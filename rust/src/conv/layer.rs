//! [`ConvLayer`] — the application model of the paper (Definitions 5–8),
//! generalized with dilation and channel groups.
//!
//! The paper's formalism covers the dense, unit-dilation convolution; the
//! two generalizations here keep every definition intact while changing
//! *which* input pixels a patch touches (dilation) and *how many* elements
//! each pixel / kernel carries (groups):
//!
//! * **Dilation** `(d_h, d_w)`: kernel taps are spaced `d_h`/`d_w` pixels
//!   apart, so a patch reads the dilated lattice
//!   `{(s_h·i + h·d_h, s_w·j + w·d_w) : h < H_K, w < W_K}` inside the
//!   bounding span `H_span = (H_K − 1)·d_h + 1`. Patch footprints are no
//!   longer solid rectangles — overlap formulas must honour the holes.
//! * **Groups** `G` (`G = C_in` ⇒ depthwise): kernel `l` convolves only the
//!   channel slice of its group, so a kernel stores `C_in/G · H_K · W_K`
//!   elements and one output value costs `C_in/G · H_K · W_K` MACs. The
//!   *spatial* footprint of a patch is unchanged — every group has kernels,
//!   so all `C_in` channels of each footprint pixel are still loaded.

use crate::conv::{Patch, PatchId};
use crate::tensor::{Dims3, PixelSet, Rect};

/// A 2D convolution layer over a (pre-padded, Remark 2) 3D input.
///
/// `O[l,i,j] = Σ_{c ∈ grp(l)} Σ_h Σ_w I[c, i·s_h + h·d_h, j·s_w + w·d_w] · K^l[c,h,w]`
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvLayer {
    /// Input channels `C_in`.
    pub c_in: usize,
    /// Input height `H_in` (after padding).
    pub h_in: usize,
    /// Input width `W_in` (after padding).
    pub w_in: usize,
    /// Kernel height `H_K`.
    pub h_k: usize,
    /// Kernel width `W_K`.
    pub w_k: usize,
    /// Number of kernels `N = C_out`.
    pub n_kernels: usize,
    /// Stride along height `s_h`.
    pub s_h: usize,
    /// Stride along width `s_w`.
    pub s_w: usize,
    /// Dilation along height `d_h` (1 = dense).
    pub d_h: usize,
    /// Dilation along width `d_w` (1 = dense).
    pub d_w: usize,
    /// Channel groups `G`: `c_in` and `n_kernels` must both divide by `G`;
    /// `G = c_in` is a depthwise convolution.
    pub groups: usize,
}

impl ConvLayer {
    /// Construct a dense (dilation 1, single-group) layer with validation —
    /// the paper's original model.
    pub fn new(
        c_in: usize,
        h_in: usize,
        w_in: usize,
        h_k: usize,
        w_k: usize,
        n_kernels: usize,
        s_h: usize,
        s_w: usize,
    ) -> Result<Self, String> {
        let l = ConvLayer {
            c_in,
            h_in,
            w_in,
            h_k,
            w_k,
            n_kernels,
            s_h,
            s_w,
            d_h: 1,
            d_w: 1,
            groups: 1,
        };
        l.validate()?;
        Ok(l)
    }

    /// Builder: same layer with dilation `(d_h, d_w)` (re-validated).
    pub fn with_dilation(mut self, d_h: usize, d_w: usize) -> Result<Self, String> {
        self.d_h = d_h;
        self.d_w = d_w;
        self.validate()?;
        Ok(self)
    }

    /// Builder: same layer with `groups` channel groups (re-validated);
    /// `groups == c_in` makes the layer depthwise.
    pub fn with_groups(mut self, groups: usize) -> Result<Self, String> {
        self.groups = groups;
        self.validate()?;
        Ok(self)
    }

    /// Square-image, square-kernel, unit-stride shorthand used throughout the
    /// paper's evaluation (§7.1).
    pub fn square(c_in: usize, h_in: usize, h_k: usize, n_kernels: usize) -> Self {
        ConvLayer::new(c_in, h_in, h_in, h_k, h_k, n_kernels, 1, 1)
            .expect("square layer parameters must be valid")
    }

    /// Check every §3 well-formedness condition, with a precise error.
    pub fn validate(&self) -> Result<(), String> {
        if self.c_in == 0 || self.h_in == 0 || self.w_in == 0 {
            return Err("input dimensions must be positive".into());
        }
        if self.h_k == 0 || self.w_k == 0 || self.n_kernels == 0 {
            return Err("kernel dimensions must be positive".into());
        }
        if self.s_h == 0 || self.s_w == 0 {
            return Err("strides must be positive".into());
        }
        if self.d_h == 0 || self.d_w == 0 {
            return Err("dilations must be positive".into());
        }
        if self.groups == 0 {
            return Err("groups must be positive".into());
        }
        if self.c_in % self.groups != 0 {
            return Err(format!(
                "groups {} must divide c_in {}",
                self.groups, self.c_in
            ));
        }
        if self.n_kernels % self.groups != 0 {
            return Err(format!(
                "groups {} must divide n_kernels {}",
                self.groups, self.n_kernels
            ));
        }
        if self.h_span() > self.h_in || self.w_span() > self.w_in {
            return Err(format!(
                "dilated kernel span {}x{} larger than input {}x{}",
                self.h_span(),
                self.w_span(),
                self.h_in,
                self.w_in
            ));
        }
        Ok(())
    }

    /// Dilated kernel extent along height: `H_span = (H_K − 1)·d_h + 1`.
    pub fn h_span(&self) -> usize {
        (self.h_k - 1) * self.d_h + 1
    }

    /// Dilated kernel extent along width: `W_span = (W_K − 1)·d_w + 1`.
    pub fn w_span(&self) -> usize {
        (self.w_k - 1) * self.d_w + 1
    }

    /// `H_out = ⌊(H_in − H_span)/s_h⌋ + 1` (input already padded,
    /// Definition 8 with the dilated span).
    pub fn h_out(&self) -> usize {
        (self.h_in - self.h_span()) / self.s_h + 1
    }

    /// `W_out = ⌊(W_in − W_span)/s_w⌋ + 1`.
    pub fn w_out(&self) -> usize {
        (self.w_in - self.w_span()) / self.s_w + 1
    }

    /// `C_out = N`.
    pub fn c_out(&self) -> usize {
        self.n_kernels
    }

    /// Input channels per group: `C_in / G`.
    pub fn channels_per_group(&self) -> usize {
        self.c_in / self.groups
    }

    /// Kernels (output channels) per group: `N / G`.
    pub fn kernels_per_group(&self) -> usize {
        self.n_kernels / self.groups
    }

    /// The group kernel `l` belongs to.
    pub fn group_of_kernel(&self, l: usize) -> usize {
        l / self.kernels_per_group()
    }

    /// Input tensor dimensions `C_in × H_in × W_in`.
    pub fn input_dims(&self) -> Dims3 {
        Dims3::new(self.c_in, self.h_in, self.w_in)
    }

    /// Output tensor dimensions `C_out × H_out × W_out` (Definition 8).
    pub fn output_dims(&self) -> Dims3 {
        Dims3::new(self.c_out(), self.h_out(), self.w_out())
    }

    /// Per-kernel storage shape: `[C_in/G, H_K, W_K]`.
    pub fn kernel_dims(&self) -> Dims3 {
        Dims3::new(self.channels_per_group(), self.h_k, self.w_k)
    }

    /// Spatial-pixel universe size (`H_in × W_in`, Remark 6).
    pub fn n_pixels(&self) -> usize {
        self.h_in * self.w_in
    }

    /// `|X| = H_out × W_out` — the number of patches (Definition 11).
    pub fn n_patches(&self) -> usize {
        self.h_out() * self.w_out()
    }

    /// Total elements of all kernels: `C_out · C_in/G · H_K · W_K`.
    pub fn kernel_elements(&self) -> usize {
        self.n_kernels * self.kernel_dims().len()
    }

    /// MACs to produce one output value (Definition 13 under groups):
    /// `nb_op_value = C_in/G · H_K · W_K`.
    pub fn ops_per_output_value(&self) -> usize {
        self.channels_per_group() * self.h_k * self.w_k
    }

    /// MACs for one S1 patch — all `C_out` channels (Property 1).
    pub fn ops_per_patch(&self) -> usize {
        self.ops_per_output_value() * self.c_out()
    }

    /// Width of an im2col row: `C_in · H_K · W_K` — the *gathered* window
    /// covers all input channels even under groups (each group's kernels
    /// read their slice of it; the rest multiplies zeros in the
    /// zero-expanded kernel matrix). Equals `ops_per_output_value · G`.
    pub fn im2col_width(&self) -> usize {
        self.c_in * self.h_k * self.w_k
    }

    /// Spatial pixels one patch touches: `H_K · W_K` (dilation spreads them
    /// out but does not change the count).
    pub fn pixels_per_patch(&self) -> usize {
        self.h_k * self.w_k
    }

    /// On-chip input elements one patch needs: all `C_in` channels of its
    /// `H_K·W_K` footprint pixels. Under groups this is *larger* than
    /// `ops_per_output_value` (which divides by `G`); memory sizing must use
    /// this, not the MAC count.
    pub fn input_elements_per_patch(&self) -> usize {
        self.pixels_per_patch() * self.c_in
    }

    /// Patch from its row-major id (Remark 4).
    pub fn patch(&self, id: PatchId) -> Patch {
        let w_out = self.w_out();
        let i = id as usize / w_out;
        let j = id as usize % w_out;
        debug_assert!(i < self.h_out(), "patch id out of range");
        Patch { id, i, j }
    }

    /// Patch id from output spatial coordinates `(i, j)`.
    pub fn patch_id(&self, i: usize, j: usize) -> PatchId {
        debug_assert!(i < self.h_out() && j < self.w_out());
        (i * self.w_out() + j) as PatchId
    }

    /// All patch ids in row-major order — the set `X` (Definition 11).
    pub fn all_patches(&self) -> impl Iterator<Item = PatchId> {
        0..self.n_patches() as PatchId
    }

    /// *Bounding* rectangle of the input pixels read by patch `(i, j)`:
    /// rows `[s_h·i, s_h·i + H_span)`, cols `[s_w·j, s_w·j + W_span)`.
    /// For `d = 1` this is exactly the footprint (Definition 10); for
    /// `d > 1` the footprint is the dilated lattice *inside* this rect —
    /// use [`ConvLayer::patch_pixels`] / [`ConvLayer::patch_overlap`] for
    /// hole-accurate sets and counts.
    pub fn patch_rect(&self, id: PatchId) -> Rect {
        let p = self.patch(id);
        Rect::new(
            self.s_h * p.i,
            self.s_h * p.i + self.h_span(),
            self.s_w * p.j,
            self.s_w * p.j + self.w_span(),
        )
    }

    /// Pixel set of one patch.
    ///
    /// Dense (`d_w = 1`) patch rows are contiguous pixel-id ranges, so
    /// insertion is word-masked (`PixelSet::insert_range`) rather than
    /// per-pixel — this is the hot path of both the simulator and the
    /// optimizer's objective. Dilated rows fall back to per-tap inserts.
    pub fn patch_pixels(&self, id: PatchId) -> PixelSet {
        let mut s = PixelSet::empty(self.n_pixels());
        self.add_patch_pixels(&mut s, id);
        s
    }

    /// Union of pixel sets of a group of patches (the group's input
    /// footprint, Definition 16).
    pub fn group_pixels(&self, group: &[PatchId]) -> PixelSet {
        let mut s = PixelSet::empty(self.n_pixels());
        for &p in group {
            self.add_patch_pixels(&mut s, p);
        }
        s
    }

    /// Allocation-free variant of [`ConvLayer::group_pixels`]: clears and
    /// refills an existing buffer (annealer hot path).
    pub fn group_pixels_into(&self, s: &mut PixelSet, group: &[PatchId]) {
        debug_assert_eq!(s.universe(), self.n_pixels());
        s.clear();
        for &p in group {
            self.add_patch_pixels(s, p);
        }
    }

    /// Contiguous pixel-id ranges `(start, end)` covering one patch's taps:
    /// one `w_k`-wide range per kernel row when `d_w = 1` (the word-masked
    /// fast path), `w_k` single-tap ranges per row otherwise. The single
    /// source of truth for the dilated footprint walk.
    #[inline]
    fn patch_row_ranges(&self, id: PatchId) -> impl Iterator<Item = (u32, u32)> + '_ {
        let p = self.patch(id);
        let (row0, col0) = (self.s_h * p.i, self.s_w * p.j);
        let (runs, run_len, step) =
            if self.d_w == 1 { (1, self.w_k, 0) } else { (self.w_k, 1, self.d_w) };
        (0..self.h_k).flat_map(move |h| {
            let row = ((row0 + h * self.d_h) * self.w_in) as u32;
            (0..runs).map(move |r| {
                let start = row + (col0 + r * step) as u32;
                (start, start + run_len as u32)
            })
        })
    }

    /// Insert one patch's pixels into an existing set (word-masked row
    /// ranges when `d_w = 1`, per-tap inserts otherwise). Public so the
    /// optimizer's delta scoring can build candidate footprints in reusable
    /// scratch buffers without intermediate sets.
    #[inline]
    pub fn add_patch_pixels(&self, s: &mut PixelSet, id: PatchId) {
        for (a, b) in self.patch_row_ranges(id) {
            s.insert_range(a, b);
        }
    }

    /// `|pix(id) ∩ set|` without materializing the patch's pixel set —
    /// word-masked popcounts over the patch's row ranges (greedy hot path);
    /// per-tap popcounts under width dilation.
    #[inline]
    pub fn patch_pixels_in(&self, set: &PixelSet, id: PatchId) -> usize {
        self.patch_row_ranges(id).map(|(a, b)| set.count_range(a, b)).sum()
    }

    /// Allocation-free check that a patch's entire footprint is contained in
    /// `resident` (used by the step semantics on every compute action).
    pub fn patch_resident(&self, resident: &PixelSet, id: PatchId) -> bool {
        self.patch_row_ranges(id).all(|(a, b)| resident.contains_range(a, b))
    }

    /// Number of common taps along one axis between two patches whose output
    /// coordinates differ by `delta_out`: both tap sets are arithmetic
    /// progressions with step `d` and length `k`, offset by `δ = |Δ|·s`; they
    /// share taps iff `d | δ`, and then `k − δ/d` of them (when positive).
    #[inline]
    fn axis_overlap(delta_out: usize, s: usize, d: usize, k: usize) -> usize {
        let off = delta_out * s;
        if off % d != 0 {
            return 0;
        }
        let m = off / d;
        if m >= k {
            0
        } else {
            k - m
        }
    }

    /// Spatial overlap (pixel count) between two individual patches —
    /// analytic on the dilated lattice, no set materialization:
    /// `(H_K − δ_h/d_h)·(W_K − δ_w/d_w)` when the dilations divide the
    /// offsets, else 0 on that axis.
    pub fn patch_overlap(&self, a: PatchId, b: PatchId) -> usize {
        let (pa, pb) = (self.patch(a), self.patch(b));
        let rows =
            Self::axis_overlap(pa.i.abs_diff(pb.i), self.s_h, self.d_h, self.h_k);
        if rows == 0 {
            return 0;
        }
        let cols =
            Self::axis_overlap(pa.j.abs_diff(pb.j), self.s_w, self.d_w, self.w_k);
        rows * cols
    }
}

impl std::fmt::Display for ConvLayer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "conv(in={}x{}x{}, k={}x{}x{}x{}, s={}x{}",
            self.c_in,
            self.h_in,
            self.w_in,
            self.n_kernels,
            self.channels_per_group(),
            self.h_k,
            self.w_k,
            self.s_h,
            self.s_w,
        )?;
        if self.d_h != 1 || self.d_w != 1 {
            write!(f, ", d={}x{}", self.d_h, self.d_w)?;
        }
        if self.groups != 1 {
            write!(f, ", g={}", self.groups)?;
        }
        write!(f, ") -> {}", self.output_dims())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The layer of Example 1: I ∈ R^{2×5×5}, two 3×3 kernels, stride 1.
    fn example1() -> ConvLayer {
        ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap()
    }

    /// 3×3 kernel dilated ×2 on a 9×9 input: span 5, 5×5 output.
    fn dilated() -> ConvLayer {
        ConvLayer::new(1, 9, 9, 3, 3, 1, 1, 1)
            .unwrap()
            .with_dilation(2, 2)
            .unwrap()
    }

    #[test]
    fn output_dims_match_definition8() {
        let l = example1();
        assert_eq!(l.h_out(), 3);
        assert_eq!(l.w_out(), 3);
        assert_eq!(l.c_out(), 2);
        assert_eq!(l.n_patches(), 9);
    }

    #[test]
    fn strided_output_dims() {
        let l = ConvLayer::new(1, 7, 9, 3, 3, 1, 2, 2).unwrap();
        assert_eq!(l.h_out(), 3);
        assert_eq!(l.w_out(), 4);
    }

    #[test]
    fn dilated_output_dims_use_span() {
        let l = dilated();
        assert_eq!((l.h_span(), l.w_span()), (5, 5));
        assert_eq!((l.h_out(), l.w_out()), (5, 5));
        // anisotropic dilation
        let l2 = ConvLayer::new(1, 9, 9, 3, 3, 1, 1, 1)
            .unwrap()
            .with_dilation(3, 1)
            .unwrap();
        assert_eq!((l2.h_span(), l2.w_span()), (7, 3));
        assert_eq!((l2.h_out(), l2.w_out()), (3, 7));
        // dilation composes with stride
        let l3 = ConvLayer::new(1, 11, 11, 3, 3, 1, 2, 2)
            .unwrap()
            .with_dilation(2, 2)
            .unwrap();
        assert_eq!((l3.h_out(), l3.w_out()), (4, 4));
    }

    #[test]
    fn ops_counts_match_definition13_property1() {
        let l = example1();
        assert_eq!(l.ops_per_output_value(), 2 * 3 * 3);
        assert_eq!(l.ops_per_patch(), 2 * 3 * 3 * 2);
    }

    #[test]
    fn grouped_ops_and_kernel_storage_shrink() {
        let l = ConvLayer::new(4, 6, 6, 3, 3, 8, 1, 1)
            .unwrap()
            .with_groups(4)
            .unwrap(); // depthwise-ish: 4 groups of 1 channel, 2 kernels each
        assert_eq!(l.channels_per_group(), 1);
        assert_eq!(l.kernels_per_group(), 2);
        assert_eq!(l.ops_per_output_value(), 9);
        assert_eq!(l.kernel_dims().len(), 9);
        assert_eq!(l.kernel_elements(), 8 * 9);
        assert_eq!(l.im2col_width(), 4 * 9);
        // memory per patch still carries all channels
        assert_eq!(l.input_elements_per_patch(), 9 * 4);
        assert_eq!(l.group_of_kernel(0), 0);
        assert_eq!(l.group_of_kernel(3), 1);
        assert_eq!(l.group_of_kernel(7), 3);
    }

    #[test]
    fn patch_rects_match_example1_figure7() {
        let l = example1();
        // P_{0,0}: top-left 3x3
        assert_eq!(l.patch_rect(l.patch_id(0, 0)), Rect::new(0, 3, 0, 3));
        // P_{1,1}: centre
        assert_eq!(l.patch_rect(l.patch_id(1, 1)), Rect::new(1, 4, 1, 4));
        // P_{2,2}: bottom-right
        assert_eq!(l.patch_rect(l.patch_id(2, 2)), Rect::new(2, 5, 2, 5));
    }

    #[test]
    fn patch_id_roundtrip() {
        let l = example1();
        for id in l.all_patches() {
            let p = l.patch(id);
            assert_eq!(l.patch_id(p.i, p.j), id);
        }
    }

    #[test]
    fn patch_pixels_count() {
        let l = example1();
        for id in l.all_patches() {
            assert_eq!(l.patch_pixels(id).len(), 9);
        }
    }

    #[test]
    fn dilated_patch_pixels_are_the_lattice() {
        let l = dilated(); // 9x9 input, 3x3 kernel d=2
        let px = l.patch_pixels(l.patch_id(0, 0));
        // taps at rows {0,2,4} × cols {0,2,4}
        assert_eq!(px.len(), 9);
        for h in [0usize, 2, 4] {
            for w in [0usize, 2, 4] {
                assert!(px.contains((h * 9 + w) as u32), "({h},{w})");
            }
        }
        // holes are absent
        assert!(!px.contains(1));
        assert!(!px.contains((1 * 9 + 1) as u32));
    }

    #[test]
    fn group_pixels_is_union() {
        let l = example1();
        let g = [l.patch_id(0, 0), l.patch_id(0, 1)];
        // adjacent patches overlap in 3x2 = 6 pixels → union = 9+9-6 = 12
        assert_eq!(l.group_pixels(&g).len(), 12);
        assert_eq!(l.patch_overlap(g[0], g[1]), 6);
    }

    #[test]
    fn patch_pixels_in_matches_intersection() {
        let layers = [
            ConvLayer::new(1, 7, 9, 3, 3, 1, 2, 2).unwrap(),
            dilated(),
            ConvLayer::new(1, 11, 9, 3, 3, 1, 2, 1)
                .unwrap()
                .with_dilation(2, 3)
                .unwrap(),
        ];
        for l in layers {
            let resident = l.group_pixels(&[0, 1, 5]);
            for id in l.all_patches() {
                assert_eq!(
                    l.patch_pixels_in(&resident, id),
                    l.patch_pixels(id).intersection_len(&resident),
                    "{l} patch {id}"
                );
            }
        }
    }

    #[test]
    fn patch_resident_matches_subset_check() {
        for l in [example1(), dilated()] {
            let resident = l.group_pixels(&[0, 3]);
            for id in l.all_patches() {
                assert_eq!(
                    l.patch_resident(&resident, id),
                    l.patch_pixels(id).is_subset_of(&resident),
                    "{l} patch {id}"
                );
            }
        }
    }

    #[test]
    fn overlap_strided() {
        // stride 3 with 3x3 kernels → adjacent patches are disjoint
        let l = ConvLayer::new(1, 9, 9, 3, 3, 1, 3, 3).unwrap();
        assert_eq!(l.patch_overlap(l.patch_id(0, 0), l.patch_id(0, 1)), 0);
    }

    /// Analytic overlap must equal the brute-force pixel-set intersection on
    /// dilated and stride+dilation layers (where the lattice has holes).
    #[test]
    fn overlap_matches_brute_force_on_dilated_layers() {
        let layers = [
            dilated(),
            // stride 2, dilation 2: offsets stay on the lattice
            ConvLayer::new(1, 11, 11, 3, 3, 1, 2, 2)
                .unwrap()
                .with_dilation(2, 2)
                .unwrap(),
            // stride 1, dilation 2: odd offsets fall into the holes
            ConvLayer::new(1, 8, 8, 2, 2, 1, 1, 1)
                .unwrap()
                .with_dilation(3, 3)
                .unwrap(),
            // anisotropic everything
            ConvLayer::new(1, 12, 10, 3, 2, 1, 2, 1)
                .unwrap()
                .with_dilation(1, 3)
                .unwrap(),
        ];
        for l in layers {
            for a in l.all_patches() {
                for b in l.all_patches() {
                    assert_eq!(
                        l.patch_overlap(a, b),
                        l.patch_pixels(a).intersection_len(&l.patch_pixels(b)),
                        "{l}: patches {a},{b}"
                    );
                }
            }
        }
    }

    #[test]
    fn dilation_holes_break_overlap_at_odd_offsets() {
        let l = dilated(); // d=2, s=1: lattices at odd offsets interleave
        // Δj = 1: columns {0,2,4} vs {1,3,5} — disjoint
        assert_eq!(l.patch_overlap(l.patch_id(0, 0), l.patch_id(0, 1)), 0);
        // Δj = 2: columns {0,2,4} vs {2,4,6} — 2 common cols × 3 rows
        assert_eq!(l.patch_overlap(l.patch_id(0, 0), l.patch_id(0, 2)), 6);
        // Δi = Δj = 2: 2×2 common taps
        assert_eq!(l.patch_overlap(l.patch_id(0, 0), l.patch_id(2, 2)), 4);
    }

    #[test]
    fn validation_rejects_bad_layers() {
        assert!(ConvLayer::new(0, 5, 5, 3, 3, 1, 1, 1).is_err());
        assert!(ConvLayer::new(1, 5, 5, 6, 3, 1, 1, 1).is_err());
        assert!(ConvLayer::new(1, 5, 5, 3, 3, 1, 0, 1).is_err());
        assert!(ConvLayer::new(1, 5, 5, 3, 3, 0, 1, 1).is_err());
        // dilated span exceeding the input
        assert!(ConvLayer::new(1, 5, 5, 3, 3, 1, 1, 1)
            .unwrap()
            .with_dilation(2, 2)
            .is_err());
        assert!(ConvLayer::new(1, 5, 5, 3, 3, 1, 1, 1)
            .unwrap()
            .with_dilation(0, 1)
            .is_err());
        // groups must divide both channel counts
        assert!(ConvLayer::new(4, 6, 6, 8, 3, 3, 1, 1).is_err()); // (kernel > input)
        assert!(ConvLayer::new(4, 6, 6, 3, 3, 8, 1, 1)
            .unwrap()
            .with_groups(3)
            .is_err());
        assert!(ConvLayer::new(4, 6, 6, 3, 3, 6, 1, 1)
            .unwrap()
            .with_groups(4)
            .is_err());
        assert!(ConvLayer::new(4, 6, 6, 3, 3, 8, 1, 1)
            .unwrap()
            .with_groups(0)
            .is_err());
    }

    #[test]
    fn kernel_elements() {
        let l = example1();
        assert_eq!(l.kernel_elements(), 2 * 2 * 3 * 3);
    }

    #[test]
    fn depthwise_is_groups_equal_c_in() {
        let l = ConvLayer::new(6, 8, 8, 3, 3, 6, 1, 1)
            .unwrap()
            .with_groups(6)
            .unwrap();
        assert_eq!(l.channels_per_group(), 1);
        assert_eq!(l.kernels_per_group(), 1);
        assert_eq!(l.kernel_elements(), 6 * 9);
        assert_eq!(l.ops_per_output_value(), 9);
    }

    #[test]
    fn display_mentions_dilation_and_groups() {
        let l = ConvLayer::new(4, 12, 12, 3, 3, 4, 1, 1)
            .unwrap()
            .with_dilation(2, 2)
            .unwrap()
            .with_groups(4)
            .unwrap();
        let s = format!("{l}");
        assert!(s.contains("d=2x2"), "{s}");
        assert!(s.contains("g=4"), "{s}");
        assert!(!format!("{}", example1()).contains("d="));
    }
}

//! [`ConvLayer`] — the application model of the paper (Definitions 5–8).

use crate::conv::{Patch, PatchId};
use crate::tensor::{Dims3, PixelSet, Rect};

/// A 2D convolution layer over a (pre-padded, Remark 2) 3D input.
///
/// `O[l,i,j] = Σ_c Σ_h Σ_w I[c, i·s_h + h, j·s_w + w] · K^l[c,h,w]`
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvLayer {
    /// Input channels `C_in`.
    pub c_in: usize,
    /// Input height `H_in` (after padding).
    pub h_in: usize,
    /// Input width `W_in` (after padding).
    pub w_in: usize,
    /// Kernel height `H_K`.
    pub h_k: usize,
    /// Kernel width `W_K`.
    pub w_k: usize,
    /// Number of kernels `N = C_out`.
    pub n_kernels: usize,
    /// Stride along height `s_h`.
    pub s_h: usize,
    /// Stride along width `s_w`.
    pub s_w: usize,
}

impl ConvLayer {
    /// Construct with validation.
    pub fn new(
        c_in: usize,
        h_in: usize,
        w_in: usize,
        h_k: usize,
        w_k: usize,
        n_kernels: usize,
        s_h: usize,
        s_w: usize,
    ) -> Result<Self, String> {
        let l = ConvLayer { c_in, h_in, w_in, h_k, w_k, n_kernels, s_h, s_w };
        l.validate()?;
        Ok(l)
    }

    /// Square-image, square-kernel, unit-stride shorthand used throughout the
    /// paper's evaluation (§7.1).
    pub fn square(c_in: usize, h_in: usize, h_k: usize, n_kernels: usize) -> Self {
        ConvLayer::new(c_in, h_in, h_in, h_k, h_k, n_kernels, 1, 1)
            .expect("square layer parameters must be valid")
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.c_in == 0 || self.h_in == 0 || self.w_in == 0 {
            return Err("input dimensions must be positive".into());
        }
        if self.h_k == 0 || self.w_k == 0 || self.n_kernels == 0 {
            return Err("kernel dimensions must be positive".into());
        }
        if self.s_h == 0 || self.s_w == 0 {
            return Err("strides must be positive".into());
        }
        if self.h_k > self.h_in || self.w_k > self.w_in {
            return Err(format!(
                "kernel {}x{} larger than input {}x{}",
                self.h_k, self.w_k, self.h_in, self.w_in
            ));
        }
        Ok(())
    }

    /// `H_out = ⌊(H_in − H_K)/s_h⌋ + 1` (input already padded, Definition 8).
    pub fn h_out(&self) -> usize {
        (self.h_in - self.h_k) / self.s_h + 1
    }

    /// `W_out = ⌊(W_in − W_K)/s_w⌋ + 1`.
    pub fn w_out(&self) -> usize {
        (self.w_in - self.w_k) / self.s_w + 1
    }

    /// `C_out = N`.
    pub fn c_out(&self) -> usize {
        self.n_kernels
    }

    pub fn input_dims(&self) -> Dims3 {
        Dims3::new(self.c_in, self.h_in, self.w_in)
    }

    pub fn output_dims(&self) -> Dims3 {
        Dims3::new(self.c_out(), self.h_out(), self.w_out())
    }

    pub fn kernel_dims(&self) -> Dims3 {
        Dims3::new(self.c_in, self.h_k, self.w_k)
    }

    /// Spatial-pixel universe size (`H_in × W_in`, Remark 6).
    pub fn n_pixels(&self) -> usize {
        self.h_in * self.w_in
    }

    /// `|X| = H_out × W_out` — the number of patches (Definition 11).
    pub fn n_patches(&self) -> usize {
        self.h_out() * self.w_out()
    }

    /// Total elements of all kernels: `C_out · C_in · H_K · W_K`.
    pub fn kernel_elements(&self) -> usize {
        self.n_kernels * self.c_in * self.h_k * self.w_k
    }

    /// MACs to produce one output value (Definition 13):
    /// `nb_op_value = C_in · H_K · W_K`.
    pub fn ops_per_output_value(&self) -> usize {
        self.c_in * self.h_k * self.w_k
    }

    /// MACs for one S1 patch — all `C_out` channels (Property 1).
    pub fn ops_per_patch(&self) -> usize {
        self.ops_per_output_value() * self.c_out()
    }

    /// Patch from its row-major id (Remark 4).
    pub fn patch(&self, id: PatchId) -> Patch {
        let w_out = self.w_out();
        let i = id as usize / w_out;
        let j = id as usize % w_out;
        debug_assert!(i < self.h_out(), "patch id out of range");
        Patch { id, i, j }
    }

    /// Patch id from output spatial coordinates `(i, j)`.
    pub fn patch_id(&self, i: usize, j: usize) -> PatchId {
        debug_assert!(i < self.h_out() && j < self.w_out());
        (i * self.w_out() + j) as PatchId
    }

    /// All patch ids in row-major order — the set `X` (Definition 11).
    pub fn all_patches(&self) -> impl Iterator<Item = PatchId> {
        0..self.n_patches() as PatchId
    }

    /// Spatial rectangle of input pixels read by patch `(i, j)`
    /// (Definition 10: rows `[s_h·i, s_h·i + H_K)`, cols `[s_w·j, s_w·j + W_K)`).
    pub fn patch_rect(&self, id: PatchId) -> Rect {
        let p = self.patch(id);
        Rect::new(
            self.s_h * p.i,
            self.s_h * p.i + self.h_k,
            self.s_w * p.j,
            self.s_w * p.j + self.w_k,
        )
    }

    /// Pixel set of one patch.
    ///
    /// Patch rows are contiguous pixel-id ranges, so insertion is word-masked
    /// (`PixelSet::insert_range`) rather than per-pixel — this is the hot
    /// path of both the simulator and the optimizer's objective.
    pub fn patch_pixels(&self, id: PatchId) -> PixelSet {
        let mut s = PixelSet::empty(self.n_pixels());
        self.add_patch_pixels(&mut s, id);
        s
    }

    /// Union of pixel sets of a group of patches (the group's input
    /// footprint, Definition 16).
    pub fn group_pixels(&self, group: &[PatchId]) -> PixelSet {
        let mut s = PixelSet::empty(self.n_pixels());
        for &p in group {
            self.add_patch_pixels(&mut s, p);
        }
        s
    }

    /// Allocation-free variant of [`ConvLayer::group_pixels`]: clears and
    /// refills an existing buffer (annealer hot path).
    pub fn group_pixels_into(&self, s: &mut PixelSet, group: &[PatchId]) {
        debug_assert_eq!(s.universe(), self.n_pixels());
        s.clear();
        for &p in group {
            self.add_patch_pixels(s, p);
        }
    }

    /// Insert one patch's pixels into an existing set (word-masked row
    /// ranges). Public so the optimizer's delta scoring can build candidate
    /// footprints in reusable scratch buffers without intermediate sets.
    #[inline]
    pub fn add_patch_pixels(&self, s: &mut PixelSet, id: PatchId) {
        let rect = self.patch_rect(id);
        for h in rect.h0..rect.h1 {
            let row = (h * self.w_in) as u32;
            s.insert_range(row + rect.w0 as u32, row + rect.w1 as u32);
        }
    }

    /// `|pix(id) ∩ set|` without materializing the patch's pixel set —
    /// word-masked popcounts over the patch's row ranges (greedy hot path).
    #[inline]
    pub fn patch_pixels_in(&self, set: &PixelSet, id: PatchId) -> usize {
        let rect = self.patch_rect(id);
        let mut n = 0;
        for h in rect.h0..rect.h1 {
            let row = (h * self.w_in) as u32;
            n += set.count_range(row + rect.w0 as u32, row + rect.w1 as u32);
        }
        n
    }

    /// Allocation-free check that a patch's entire footprint is contained in
    /// `resident` (used by the step semantics on every compute action).
    pub fn patch_resident(&self, resident: &PixelSet, id: PatchId) -> bool {
        let rect = self.patch_rect(id);
        for h in rect.h0..rect.h1 {
            let row = (h * self.w_in) as u32;
            if !resident.contains_range(row + rect.w0 as u32, row + rect.w1 as u32) {
                return false;
            }
        }
        true
    }

    /// Spatial overlap (pixel count) between two individual patches.
    pub fn patch_overlap(&self, a: PatchId, b: PatchId) -> usize {
        match self.patch_rect(a).intersect(&self.patch_rect(b)) {
            Some(r) => r.area(),
            None => 0,
        }
    }
}

impl std::fmt::Display for ConvLayer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "conv(in={}x{}x{}, k={}x{}x{}x{}, s={}x{}) -> {}",
            self.c_in, self.h_in, self.w_in,
            self.n_kernels, self.c_in, self.h_k, self.w_k,
            self.s_h, self.s_w,
            self.output_dims(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The layer of Example 1: I ∈ R^{2×5×5}, two 3×3 kernels, stride 1.
    fn example1() -> ConvLayer {
        ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap()
    }

    #[test]
    fn output_dims_match_definition8() {
        let l = example1();
        assert_eq!(l.h_out(), 3);
        assert_eq!(l.w_out(), 3);
        assert_eq!(l.c_out(), 2);
        assert_eq!(l.n_patches(), 9);
    }

    #[test]
    fn strided_output_dims() {
        let l = ConvLayer::new(1, 7, 9, 3, 3, 1, 2, 2).unwrap();
        assert_eq!(l.h_out(), 3);
        assert_eq!(l.w_out(), 4);
    }

    #[test]
    fn ops_counts_match_definition13_property1() {
        let l = example1();
        assert_eq!(l.ops_per_output_value(), 2 * 3 * 3);
        assert_eq!(l.ops_per_patch(), 2 * 3 * 3 * 2);
    }

    #[test]
    fn patch_rects_match_example1_figure7() {
        let l = example1();
        // P_{0,0}: top-left 3x3
        assert_eq!(l.patch_rect(l.patch_id(0, 0)), Rect::new(0, 3, 0, 3));
        // P_{1,1}: centre
        assert_eq!(l.patch_rect(l.patch_id(1, 1)), Rect::new(1, 4, 1, 4));
        // P_{2,2}: bottom-right
        assert_eq!(l.patch_rect(l.patch_id(2, 2)), Rect::new(2, 5, 2, 5));
    }

    #[test]
    fn patch_id_roundtrip() {
        let l = example1();
        for id in l.all_patches() {
            let p = l.patch(id);
            assert_eq!(l.patch_id(p.i, p.j), id);
        }
    }

    #[test]
    fn patch_pixels_count() {
        let l = example1();
        for id in l.all_patches() {
            assert_eq!(l.patch_pixels(id).len(), 9);
        }
    }

    #[test]
    fn group_pixels_is_union() {
        let l = example1();
        let g = [l.patch_id(0, 0), l.patch_id(0, 1)];
        // adjacent patches overlap in 3x2 = 6 pixels → union = 9+9-6 = 12
        assert_eq!(l.group_pixels(&g).len(), 12);
        assert_eq!(l.patch_overlap(g[0], g[1]), 6);
    }

    #[test]
    fn patch_pixels_in_matches_intersection() {
        let l = ConvLayer::new(1, 7, 9, 3, 3, 1, 2, 2).unwrap();
        let resident = l.group_pixels(&[0, 1, 5]);
        for id in l.all_patches() {
            assert_eq!(
                l.patch_pixels_in(&resident, id),
                l.patch_pixels(id).intersection_len(&resident),
                "patch {id}"
            );
        }
    }

    #[test]
    fn overlap_strided() {
        // stride 3 with 3x3 kernels → adjacent patches are disjoint
        let l = ConvLayer::new(1, 9, 9, 3, 3, 1, 3, 3).unwrap();
        assert_eq!(l.patch_overlap(l.patch_id(0, 0), l.patch_id(0, 1)), 0);
    }

    #[test]
    fn validation_rejects_bad_layers() {
        assert!(ConvLayer::new(0, 5, 5, 3, 3, 1, 1, 1).is_err());
        assert!(ConvLayer::new(1, 5, 5, 6, 3, 1, 1, 1).is_err());
        assert!(ConvLayer::new(1, 5, 5, 3, 3, 1, 0, 1).is_err());
        assert!(ConvLayer::new(1, 5, 5, 3, 3, 0, 1, 1).is_err());
    }

    #[test]
    fn kernel_elements() {
        let l = example1();
        assert_eq!(l.kernel_elements(), 2 * 2 * 3 * 3);
    }
}

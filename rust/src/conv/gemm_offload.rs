//! Convolution-as-GeMM offloading (the TMMA/VTA adaptation of §1.3 and the
//! §8 im2col discussion).
//!
//! GeMM-based accelerators (TMMA, VTA) execute convolutions as
//! `C = A × B` with `A = im2col(I) ∈ R^{|X| × D}` and
//! `B = kernels ∈ R^{D × N}` (`D = C_in·H_K·W_K`). The block-GeMM schedule
//! slices `A` into `m_tile × k_tile` tiles and `B` into `k_tile × n_tile`
//! tiles, accumulating partial products on chip — each tile pass is a step
//! of the same formalism (free / write / load / compute).
//!
//! The key §8 observation this module quantifies: **im2col duplicates the
//! overlapping pixels**, so the GeMM path has no inter-step data reuse —
//! every element of `A` (size `|X|·D ≥ C_in·H_in·W_in`) is loaded at least
//! once per k-sweep, whereas the direct S1 strategies load each input
//! element `≤ nb_data_reload` times. [`compare_with_s1`] reports the ratio.

use crate::conv::ConvLayer;
use crate::platform::Accelerator;

/// Block-GeMM tiling parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmTiling {
    /// Rows of `A` per tile (patches per step).
    pub m_tile: usize,
    /// Contraction slice per tile.
    pub k_tile: usize,
    /// Columns of `B` per tile (kernels per step).
    pub n_tile: usize,
}

/// Cost model of a block-GeMM offload schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GemmOffloadCost {
    /// GeMM dimensions `(M, K, N) = (|X|, D, N)`.
    pub m: usize,
    /// GeMM reduction depth `K = D` (im2col row width).
    pub k: usize,
    /// GeMM output width `N` (kernel count).
    pub n: usize,
    /// Number of compute steps (tile passes).
    pub steps: u64,
    /// Elements of `A` loaded (with duplication!).
    pub a_loaded: u64,
    /// Elements of `B` loaded.
    pub b_loaded: u64,
    /// Partial-`C` elements written back (one per output per k-sweep chunk
    /// beyond the first, plus the final write).
    pub c_written: u64,
    /// Peak on-chip elements during a step.
    pub peak_occupancy: u64,
}

impl GemmOffloadCost {
    /// Duration under the platform's linear model (Definition 3 applied to
    /// the tile steps).
    pub fn duration(&self, acc: &Accelerator) -> u64 {
        (self.a_loaded + self.b_loaded) * acc.t_l
            + self.c_written * acc.t_w
            + self.steps * acc.t_acc
    }

    /// im2col duplication factor: elements of `A` across all `G` group GeMMs
    /// (`|X| · D_g · G = |X| · C_in·H_K·W_K`) vs distinct input elements.
    pub fn duplication_factor(&self, layer: &ConvLayer) -> f64 {
        (self.m * self.k * layer.groups) as f64 / layer.input_dims().len() as f64
    }
}

/// Analyze a block-GeMM schedule for `layer` under `tiling`.
///
/// Loop order is the standard output-stationary `for mi / for ni / for ki`:
/// a `C` tile stays resident across the k-sweep (accumulation), `A` and `B`
/// tiles stream. `B` tiles are re-loaded once per `mi` (no persistent cache,
/// matching the BRAM-per-step model of §1.3's TMMA).
///
/// A grouped layer (`G > 1`) is **not** one big GeMM: it is `G` independent
/// GeMMs of shape `[|X|, D_g] × [D_g, N/G]` with `D_g = C_in/G·H_K·W_K`
/// (the per-group contraction, i.e. `ops_per_output_value`). The schedule
/// runs them back to back, so steps and `A`/`B` traffic scale by the
/// per-group loop counts × `G` — the historical single-GeMM formula silently
/// assumed `G = 1` ("`c_in`-dense"); see the `grouped_*` regression tests.
pub fn analyze(layer: &ConvLayer, tiling: GemmTiling) -> Result<GemmOffloadCost, String> {
    let g = layer.groups as u64;
    let m = layer.n_patches();
    let k = layer.ops_per_output_value(); // per-group contraction depth D_g
    let n_g = layer.kernels_per_group(); // columns of one group's GeMM
    if tiling.m_tile == 0 || tiling.k_tile == 0 || tiling.n_tile == 0 {
        return Err("tile sizes must be ≥ 1".into());
    }
    let mi = m.div_ceil(tiling.m_tile) as u64;
    let ki = k.div_ceil(tiling.k_tile) as u64;
    let ni = n_g.div_ceil(tiling.n_tile) as u64;

    // Every (group, mi, ni, ki) tuple is one step.
    let steps = g * mi * ni * ki;
    // A tiles: per group, the group's k extent streams once per ni.
    let a_loaded = g * (m * k) as u64 * ni;
    // B tiles: per group, the group's B streams once per mi.
    let b_loaded = g * (k * n_g) as u64 * mi;
    // C: written back once per (group, mi, ni) after its k-sweep (partials
    // stay on chip during the sweep) = all outputs once.
    let c_written = (m * layer.n_kernels) as u64;
    // Peak: one A tile + one B tile + one C tile.
    let peak = (tiling.m_tile * tiling.k_tile
        + tiling.k_tile * tiling.n_tile
        + tiling.m_tile * tiling.n_tile) as u64;

    Ok(GemmOffloadCost {
        m,
        k,
        n: layer.n_kernels,
        steps,
        a_loaded,
        b_loaded,
        c_written,
        peak_occupancy: peak,
    })
}

/// Pick the duration-minimizing tiling that fits `size_MEM` (exhaustive over
/// divisor-ish candidates — the spaces are tiny).
pub fn best_tiling(layer: &ConvLayer, acc: &Accelerator) -> Option<(GemmTiling, GemmOffloadCost)> {
    let m = layer.n_patches();
    let k = layer.ops_per_output_value();
    let n = layer.kernels_per_group(); // one group's GeMM columns
    let mut best: Option<(GemmTiling, GemmOffloadCost, u64)> = None;
    let candidates = |dim: usize| -> Vec<usize> {
        let mut v: Vec<usize> = [1usize, 2, 4, 8, 16, 32, 64, 128]
            .into_iter()
            .filter(|&x| x <= dim)
            .collect();
        if !v.contains(&dim) {
            v.push(dim);
        }
        v
    };
    for &mt in &candidates(m) {
        for &kt in &candidates(k) {
            for &nt in &candidates(n) {
                let tiling = GemmTiling { m_tile: mt, k_tile: kt, n_tile: nt };
                let cost = analyze(layer, tiling).expect("valid tiles");
                if cost.peak_occupancy > acc.size_mem {
                    continue;
                }
                // respect the MAC bound per step too
                let macs = (mt * kt * nt) as u64;
                if macs > acc.nbop_pe {
                    continue;
                }
                let d = cost.duration(acc);
                if best.as_ref().map_or(true, |&(_, _, bd)| d < bd) {
                    best = Some((tiling, cost, d));
                }
            }
        }
    }
    best.map(|(t, c, _)| (t, c))
}

/// Compare the best GeMM schedule with a direct-S1 strategy's loads: returns
/// `(gemm_duration, s1_duration, input_traffic_ratio)`.
pub fn compare_with_s1(
    layer: &ConvLayer,
    acc: &Accelerator,
    s1_strategy: &crate::strategy::GroupedStrategy,
) -> Option<(u64, u64, f64)> {
    let (_, gemm) = best_tiling(layer, acc)?;
    let gemm_dur = gemm.duration(acc);
    let s1_dur =
        crate::optimizer::grouping_duration(layer, acc, &s1_strategy.groups);
    let s1_loads =
        crate::optimizer::grouping_loads(layer, &s1_strategy.groups) * layer.c_in as u64;
    let ratio = gemm.a_loaded as f64 / s1_loads.max(1) as f64;
    Some((gemm_dur, s1_dur, ratio))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::strategy;

    fn layer() -> ConvLayer {
        ConvLayer::new(1, 12, 12, 3, 3, 4, 1, 1).unwrap() // M=100, K=9, N=4
    }

    #[test]
    fn analyze_counts_steps_and_traffic() {
        let l = layer();
        let t = GemmTiling { m_tile: 10, k_tile: 9, n_tile: 4 };
        let c = analyze(&l, t).unwrap();
        assert_eq!((c.m, c.k, c.n), (100, 9, 4));
        assert_eq!(c.steps, 10); // 10 × 1 × 1
        assert_eq!(c.a_loaded, 900); // full A once (ni = 1)
        assert_eq!(c.b_loaded, 36 * 10); // B per mi
        assert_eq!(c.c_written, 400);
        assert_eq!(c.peak_occupancy, (90 + 36 + 40) as u64);
    }

    #[test]
    fn duplication_factor_reflects_im2col_overhead() {
        let l = layer();
        let c = analyze(&l, GemmTiling { m_tile: 100, k_tile: 9, n_tile: 4 }).unwrap();
        // A = 100×9 = 900 elements vs 144 distinct inputs → 6.25×
        let f = c.duplication_factor(&l);
        assert!((f - 6.25).abs() < 1e-9);
    }

    #[test]
    fn best_tiling_fits_constraints() {
        let l = layer();
        let acc = Accelerator::paper_eval(360, 200);
        let (t, c) = best_tiling(&l, &acc).expect("some tiling fits");
        assert!(c.peak_occupancy <= acc.size_mem);
        assert!((t.m_tile * t.k_tile * t.n_tile) as u64 <= acc.nbop_pe);
    }

    #[test]
    fn no_tiling_fits_tiny_memory() {
        let l = layer();
        let acc = Accelerator::paper_eval(100, 2);
        assert!(best_tiling(&l, &acc).is_none());
    }

    #[test]
    fn s1_beats_gemm_on_input_traffic() {
        // The §8 claim: duplicated patches ⇒ no reuse opportunity for GeMM.
        let l = layer();
        let acc = Accelerator::for_group_size(&l, 4);
        let s1 = strategy::zigzag(&l, 4);
        let (gemm_dur, s1_dur, ratio) = compare_with_s1(&l, &acc, &s1).unwrap();
        assert!(
            ratio > 2.0,
            "im2col duplication should multiply input traffic (got {ratio:.2})"
        );
        assert!(
            gemm_dur > s1_dur,
            "direct S1 should beat GeMM under the same machine: {gemm_dur} vs {s1_dur}"
        );
    }

    #[test]
    fn rejects_zero_tiles() {
        let l = layer();
        assert!(analyze(&l, GemmTiling { m_tile: 0, k_tile: 1, n_tile: 1 }).is_err());
    }

    /// Regression for the `c_in`-dense assumption: a grouped layer is `G`
    /// back-to-back GeMMs over the per-group contraction `D_g`, not one
    /// full-width GeMM.
    #[test]
    fn grouped_gemm_counts_per_group_sweeps() {
        let l = ConvLayer::new(4, 8, 8, 3, 3, 4, 1, 1)
            .unwrap()
            .with_groups(2)
            .unwrap(); // M = 36, D_g = 2·9 = 18, N/G = 2
        let t = GemmTiling { m_tile: 36, k_tile: 18, n_tile: 2 };
        let c = analyze(&l, t).unwrap();
        assert_eq!((c.m, c.k, c.n), (36, 18, 4));
        assert_eq!(c.steps, 2); // one tile pass per group
        assert_eq!(c.a_loaded, 2 * 36 * 18); // per-group A streams once each
        assert_eq!(c.b_loaded, 2 * 18 * 2); // per-group B once
        assert_eq!(c.c_written, 36 * 4); // every output exactly once
        // duplication counts all G sweeps: 36·18·2 / (4·64)
        assert!((c.duplication_factor(&l) - 1296.0 / 256.0).abs() < 1e-9);
    }

    /// Depthwise (G = C_in): per-group contraction collapses to H_K·W_K and
    /// the best tiling must still satisfy the machine bounds.
    #[test]
    fn depthwise_best_tiling_fits() {
        let l = ConvLayer::new(4, 10, 10, 3, 3, 4, 1, 1)
            .unwrap()
            .with_groups(4)
            .unwrap();
        assert_eq!(l.ops_per_output_value(), 9);
        let acc = Accelerator::paper_eval(576, 300);
        let (t, c) = best_tiling(&l, &acc).expect("some tiling fits");
        assert!(c.peak_occupancy <= acc.size_mem);
        assert!((t.m_tile * t.k_tile * t.n_tile) as u64 <= acc.nbop_pe);
        assert!(t.n_tile <= l.kernels_per_group());
        assert!(t.k_tile <= l.ops_per_output_value());
    }
}

//! Convolution layer model (§3 of the paper).
//!
//! * [`ConvLayer`] — Definitions 5–8: dimensions, strides, output shape.
//!   Inputs are assumed pre-padded (Remark 2).
//! * [`Patch`] / [`PatchId`] — Definition 10–11: the input slice feeding one
//!   output spatial position, and the set `X` of all patches.
//! * [`reference`] — a pure-Rust convolution oracle plus im2col, used by the
//!   functional simulation (fast path) and to cross-check the PJRT-executed
//!   AOT kernels.

pub mod gemm_offload;
mod layer;
mod patch;
pub mod reference;

pub use layer::ConvLayer;
pub use patch::{Patch, PatchId};

//! Shared atomic counters for the planning service surfaces.
//!
//! The sharded strategy cache and the batch planner account their traffic
//! here so every surface — the `plan-batch` CLI table, `BatchReport` JSON,
//! and future service endpoints — reads one set of numbers. Counters are
//! plain relaxed `AtomicU64`s: they are monotonic tallies, never used for
//! synchronization, so relaxed ordering is sufficient and keeps the cache
//! hot path free of fences.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Live hit/miss/eviction tallies of one strategy-cache instance.
///
/// Shared across planner threads behind an `Arc`; snapshot with
/// [`CacheCounters::snapshot`] for reporting.
#[derive(Debug, Default)]
pub struct CacheCounters {
    /// Lookups answered from a shard's entry map.
    pub hits: AtomicU64,
    /// Lookups that found no (valid) entry.
    pub misses: AtomicU64,
    /// Entries dropped because a shard exceeded its capacity.
    pub evictions: AtomicU64,
    /// Shard files that failed to load and were treated as empty.
    pub corrupt_shards: AtomicU64,
    /// Shard mutexes found poisoned (a holder panicked) whose in-memory
    /// state was discarded and rebuilt from disk on next access.
    pub quarantined_shards: AtomicU64,
}

impl CacheCounters {
    /// A fresh zeroed counter set.
    pub fn new() -> Self {
        CacheCounters::default()
    }

    /// Consistent-enough point-in-time copy for reports (individual loads
    /// are relaxed; the counters are independent tallies).
    pub fn snapshot(&self) -> CacheCounterSnapshot {
        CacheCounterSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            corrupt_shards: self.corrupt_shards.load(Ordering::Relaxed),
            quarantined_shards: self.quarantined_shards.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`CacheCounters`], embedded in batch reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheCounterSnapshot {
    /// Lookups answered from a shard's entry map.
    pub hits: u64,
    /// Lookups that found no (valid) entry.
    pub misses: u64,
    /// Entries dropped because a shard exceeded its capacity.
    pub evictions: u64,
    /// Shard files that failed to load and were treated as empty.
    pub corrupt_shards: u64,
    /// Shard mutexes recovered from lock poisoning (state discarded and
    /// reloaded from the persisted shard file).
    pub quarantined_shards: u64,
}

impl CacheCounterSnapshot {
    /// JSON form (canonical field order) for `BatchReport` / bench exports.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("hits", self.hits)
            .set("misses", self.misses)
            .set("evictions", self.evictions)
            .set("corrupt_shards", self.corrupt_shards)
            .set("quarantined_shards", self.quarantined_shards);
        o
    }

    /// One-line human form for CLI summaries.
    pub fn summary_line(&self) -> String {
        let mut line = format!(
            "cache counters: {} hits / {} misses / {} evictions / {} corrupt shards",
            self.hits, self.misses, self.evictions, self.corrupt_shards
        );
        if self.quarantined_shards > 0 {
            line.push_str(&format!(" / {} quarantined shards", self.quarantined_shards));
        }
        line
    }
}

/// Live request tallies of one `plan-server` process.
///
/// One instance per server, shared by the acceptor, admission queue and
/// worker behind an `Arc`; surfaced verbatim by the `stats` protocol verb.
/// Same discipline as [`CacheCounters`]: relaxed monotonic tallies.
#[derive(Debug, Default)]
pub struct ServerCounters {
    /// Requests admitted into the bounded queue.
    pub accepted: AtomicU64,
    /// Requests rejected because the queue was full (`overloaded`).
    pub rejected_overloaded: AtomicU64,
    /// Requests rejected at validation (malformed, oversized, unknown op).
    pub rejected_malformed: AtomicU64,
    /// Requests whose deadline expired while they executed (served
    /// best-so-far, tagged `degraded: deadline`).
    pub deadline_expired: AtomicU64,
    /// Requests answered below the full-portfolio ladder rung (any cause:
    /// queue pressure or deadline budget).
    pub degraded: AtomicU64,
    /// Journal entries replayed on warm restart.
    pub journal_replayed: AtomicU64,
}

impl ServerCounters {
    /// A fresh zeroed counter set.
    pub fn new() -> Self {
        ServerCounters::default()
    }

    /// Point-in-time copy for the `stats` verb.
    pub fn snapshot(&self) -> ServerCounterSnapshot {
        ServerCounterSnapshot {
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected_overloaded: self.rejected_overloaded.load(Ordering::Relaxed),
            rejected_malformed: self.rejected_malformed.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            journal_replayed: self.journal_replayed.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`ServerCounters`] for `stats` responses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServerCounterSnapshot {
    /// Requests admitted into the bounded queue.
    pub accepted: u64,
    /// Requests rejected because the queue was full.
    pub rejected_overloaded: u64,
    /// Requests rejected at validation.
    pub rejected_malformed: u64,
    /// Requests whose deadline expired mid-execution.
    pub deadline_expired: u64,
    /// Requests answered below the full-portfolio rung.
    pub degraded: u64,
    /// Journal entries replayed on warm restart.
    pub journal_replayed: u64,
}

impl ServerCounterSnapshot {
    /// JSON form (canonical field order) for the `stats` verb.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("accepted", self.accepted)
            .set("rejected_overloaded", self.rejected_overloaded)
            .set("rejected_malformed", self.rejected_malformed)
            .set("deadline_expired", self.deadline_expired)
            .set("degraded", self.degraded)
            .set("journal_replayed", self.journal_replayed);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_increments() {
        let c = CacheCounters::new();
        c.hits.fetch_add(3, Ordering::Relaxed);
        c.misses.fetch_add(2, Ordering::Relaxed);
        c.evictions.fetch_add(1, Ordering::Relaxed);
        let s = c.snapshot();
        assert_eq!((s.hits, s.misses, s.evictions, s.corrupt_shards), (3, 2, 1, 0));
        assert_eq!(s.quarantined_shards, 0);
    }

    #[test]
    fn json_and_summary_forms() {
        let s = CacheCounterSnapshot {
            hits: 7,
            misses: 1,
            evictions: 0,
            corrupt_shards: 2,
            quarantined_shards: 0,
        };
        let j = s.to_json();
        assert_eq!(j.get("hits").unwrap().as_u64(), Some(7));
        assert_eq!(j.get("corrupt_shards").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("quarantined_shards").unwrap().as_u64(), Some(0));
        assert!(s.summary_line().contains("7 hits / 1 misses"));
        assert!(!s.summary_line().contains("quarantined"), "quiet when zero");
        let q = CacheCounterSnapshot { quarantined_shards: 3, ..s };
        assert!(q.summary_line().contains("3 quarantined shards"));
    }

    #[test]
    fn server_counters_snapshot_and_json() {
        let c = ServerCounters::new();
        c.accepted.fetch_add(5, Ordering::Relaxed);
        c.rejected_overloaded.fetch_add(2, Ordering::Relaxed);
        c.deadline_expired.fetch_add(1, Ordering::Relaxed);
        let s = c.snapshot();
        assert_eq!(s.accepted, 5);
        assert_eq!(s.rejected_overloaded, 2);
        assert_eq!(s.rejected_malformed, 0);
        assert_eq!(s.deadline_expired, 1);
        let j = s.to_json();
        assert_eq!(j.get("accepted").unwrap().as_u64(), Some(5));
        assert_eq!(j.get("journal_replayed").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn concurrent_increments_are_lossless() {
        let c = std::sync::Arc::new(CacheCounters::new());
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let c = std::sync::Arc::clone(&c);
                scope.spawn(move || {
                    for _ in 0..1000 {
                        c.hits.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(c.snapshot().hits, 8_000);
    }
}

//! Performance metrics and TPU-mapping estimates (§Perf of DESIGN.md).
//!
//! `interpret=True` Pallas gives no hardware wall-clock, so Layer-1
//! performance on a real TPU is *estimated* from the BlockSpec structure:
//! VMEM footprint of one grid step, MXU-tile utilization of the GEMM shape,
//! and the arithmetic-intensity/roofline ratio. These numbers feed
//! EXPERIMENTS.md §Perf and the `convoffload perf` CLI.
//!
//! The [`counters`] submodule holds the service-side observability pieces:
//! the atomic hit/miss/eviction tallies the sharded strategy cache and the
//! batch planner report through (`plan-batch`, `BatchReport`).

pub mod counters;

pub use counters::{
    CacheCounterSnapshot, CacheCounters, ServerCounterSnapshot, ServerCounters,
};

use crate::conv::ConvLayer;

/// TPU-generation parameters used for the estimates (v4-like defaults).
#[derive(Debug, Clone, Copy)]
pub struct TpuModel {
    /// VMEM bytes available per core.
    pub vmem_bytes: u64,
    /// MXU systolic tile (lanes × sublanes), f32 elements.
    pub mxu_tile: usize,
    /// Peak MACs/cycle of the MXU.
    pub macs_per_cycle: u64,
    /// HBM→VMEM bandwidth, bytes per cycle.
    pub hbm_bytes_per_cycle: f64,
}

impl Default for TpuModel {
    fn default() -> Self {
        TpuModel {
            vmem_bytes: 16 << 20, // 16 MiB
            mxu_tile: 128,
            macs_per_cycle: 128 * 128,
            hbm_bytes_per_cycle: 600.0,
        }
    }
}

/// Static estimate for one step-compute kernel invocation.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelEstimate {
    /// `[tile_g, D]` patch tile + `[D, N]` kernels + `[tile_g, N]` out tile.
    pub vmem_bytes: u64,
    /// Fraction of the VMEM budget used.
    pub vmem_fraction: f64,
    /// MACs of one grid step.
    pub macs: u64,
    /// MXU utilization: how full the systolic tiles are, given that the MXU
    /// processes `mxu_tile × mxu_tile` panels (small D/N waste lanes).
    pub mxu_utilization: f64,
    /// Arithmetic intensity: MACs per HBM byte moved (per grid step).
    pub arithmetic_intensity: f64,
    /// Roofline-limited efficiency: min(1, AI / (peak MACs/cycle ÷ HBM B/cycle)).
    pub roofline_efficiency: f64,
}

/// Estimate the per-grid-step cost of `step_gemm` for a layer with group
/// tile `tile_g`, following the L1 BlockSpec in
/// `python/compile/kernels/step_conv.py`.
pub fn estimate_step_kernel(
    layer: &ConvLayer,
    tile_g: usize,
    tpu: &TpuModel,
) -> KernelEstimate {
    // The executed step GEMM contracts over the full im2col width (grouped
    // layers use a zero-expanded kernel matrix — see conv::reference), so
    // the estimate must size the same shape, not the per-group MAC count.
    let d = layer.im2col_width();
    let n = layer.n_kernels;
    let f32b = 4u64;
    let vmem = f32b * (tile_g * d + d * n + tile_g * n) as u64;
    let macs = (tile_g * d * n) as u64;

    // The MXU multiplies mxu_tile×mxu_tile panels; a [tile_g, d] × [d, n]
    // GEMM occupies ceil-padded panels.
    let t = tpu.mxu_tile;
    let padded = (tile_g.div_ceil(t) * t) * (d.div_ceil(t) * t) * (n.div_ceil(t) * t);
    let effective = tile_g * d * n;
    let mxu_utilization = effective as f64 / padded as f64;

    // Bytes moved per grid step: the patch tile streams in, the out tile
    // streams back; kernels are resident across the grid.
    let bytes_moved = f32b as f64 * (tile_g * d + tile_g * n) as f64;
    let arithmetic_intensity = macs as f64 / bytes_moved;
    let machine_balance = tpu.macs_per_cycle as f64 / tpu.hbm_bytes_per_cycle;
    let roofline_efficiency = (arithmetic_intensity / machine_balance).min(1.0);

    KernelEstimate {
        vmem_bytes: vmem,
        vmem_fraction: vmem as f64 / tpu.vmem_bytes as f64,
        macs,
        mxu_utilization,
        arithmetic_intensity,
        roofline_efficiency,
    }
}

/// Map the paper's abstract accelerator onto the TPU model: the on-chip
/// memory constraint (Eq. 12) becomes a VMEM budget check for the largest
/// step of a strategy.
pub fn step_fits_vmem(
    layer: &ConvLayer,
    peak_occupancy_elements: u64,
    tpu: &TpuModel,
) -> bool {
    let _ = layer;
    peak_occupancy_elements * 4 <= tpu.vmem_bytes
}

/// Human-readable report block for EXPERIMENTS.md / the CLI.
pub fn format_estimate(layer: &ConvLayer, tile_g: usize, est: &KernelEstimate) -> String {
    format!(
        "kernel step_gemm {layer} tile_g={tile_g}\n\
         \x20 VMEM/step      : {} B ({:.3}% of budget)\n\
         \x20 MACs/step      : {}\n\
         \x20 MXU utilization: {:.4}\n\
         \x20 arith intensity: {:.2} MAC/B\n\
         \x20 roofline eff   : {:.4}\n",
        est.vmem_bytes,
        est.vmem_fraction * 100.0,
        est.macs,
        est.mxu_utilization,
        est.arithmetic_intensity,
        est.roofline_efficiency,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_conv2_estimate_is_sane() {
        let l = ConvLayer::new(6, 14, 14, 5, 5, 16, 1, 1).unwrap();
        let est = estimate_step_kernel(&l, 8, &TpuModel::default());
        // 8x150 + 150x16 + 8x16 floats = (1200 + 2400 + 128)*4
        assert_eq!(est.vmem_bytes, 4 * (8 * 150 + 150 * 16 + 8 * 16) as u64);
        assert!(est.vmem_fraction < 0.01, "tiny step fits easily");
        assert_eq!(est.macs, (8 * 150 * 16) as u64);
        assert!(est.mxu_utilization > 0.0 && est.mxu_utilization <= 1.0);
        assert!(est.roofline_efficiency > 0.0 && est.roofline_efficiency <= 1.0);
    }

    #[test]
    fn bigger_tiles_improve_utilization() {
        let l = ConvLayer::new(6, 14, 14, 5, 5, 16, 1, 1).unwrap();
        let small = estimate_step_kernel(&l, 1, &TpuModel::default());
        let big = estimate_step_kernel(&l, 128, &TpuModel::default());
        assert!(big.mxu_utilization > small.mxu_utilization);
        assert!(big.arithmetic_intensity >= small.arithmetic_intensity);
    }

    #[test]
    fn vmem_budget_check() {
        let l = ConvLayer::square(1, 8, 3, 1);
        let tpu = TpuModel::default();
        assert!(step_fits_vmem(&l, 100, &tpu));
        assert!(!step_fits_vmem(&l, tpu.vmem_bytes, &tpu));
    }

    #[test]
    fn report_formats() {
        let l = ConvLayer::square(1, 8, 3, 1);
        let est = estimate_step_kernel(&l, 8, &TpuModel::default());
        let text = format_estimate(&l, 8, &est);
        assert!(text.contains("VMEM/step"));
        assert!(text.contains("MXU utilization"));
    }
}

//! Simulation reports: per-step records and strategy-level aggregates.

use crate::platform::{Accelerator, OverlapMode};
use crate::step::{StepCost, StepTiming, StrategyCost};
use crate::util::json::Json;

/// Metrics for one executed step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    /// Step index (0-based; the terminal flush is the last record).
    pub index: usize,
    /// Elements loaded / written and MACs performed.
    pub cost: StepCost,
    /// Step duration in cycles.
    pub duration: u64,
    /// `size_i^step` — element occupancy after loads + compute.
    pub occupancy: u64,
    /// Input elements resident at the end of the step (`|M_i^inp|·C_in`).
    pub resident_input_elements: u64,
    /// Patches computed this step.
    pub group_len: usize,
    /// Phase placement on the two-resource timeline — present only under
    /// [`OverlapMode::DoubleBuffered`].
    pub timing: Option<StepTiming>,
}

/// Result of simulating a full strategy.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Name of the simulated strategy (as reported by its generator).
    pub strategy_name: String,
    /// Per-step records in execution order (terminal flush included).
    pub steps: Vec<StepRecord>,
    /// Aggregated loads / writes / MACs over all steps.
    pub totals: StrategyCost,
    /// Total duration in cycles under the simulated [`OverlapMode`]: the
    /// Definition-3 sum when sequential, the two-resource critical-path
    /// makespan when double-buffered.
    pub duration: u64,
    /// The Definition-3 sequential duration `δ = Σ δ(s_i)` — always
    /// recorded, so the hidden transfer time `sequential_duration −
    /// duration` is available in any mode.
    pub sequential_duration: u64,
    /// Which overlap semantics produced `duration`.
    pub overlap: OverlapMode,
    /// Total cycles the DMA channels were busy (loads + writes, all
    /// channels summed).
    pub dma_busy: u64,
    /// Total cycles the compute units were busy (all units summed).
    pub compute_busy: u64,
    /// Busy cycles per DMA channel, indexed by channel — the timeline's
    /// actual assignments in double-buffered mode, a single-entry vector in
    /// sequential mode (one channel by construction). Sums to `dma_busy`.
    pub dma_busy_per: Vec<u64>,
    /// Busy cycles per compute unit; sums to `compute_busy`.
    pub compute_busy_per: Vec<u64>,
    /// Peak element occupancy across steps.
    pub peak_occupancy: u64,
    /// DMA retries injected by the run's [`crate::platform::FaultModel`]
    /// (0 without one).
    pub fault_retries: u64,
    /// `MemoryShrink` events that fired during the run (0 without faults).
    pub mem_shrink_events: u64,
    /// Analytic k-fault worst case
    /// ([`crate::platform::FaultModel::makespan_under_k_faults`]) evaluated
    /// at `k = fault_retries` — present only for fault-injected runs, and
    /// always ≥ `duration`.
    pub wcet_bound: Option<u64>,
    /// Element-domain communication floor on `loaded_elements`
    /// ([`crate::planner::certify::comm_lower_bound`]'s
    /// `load_element_floor`, batch-aware: kernels amortize across images).
    /// Filled by the engine; 0 until a run completes.
    pub comm_lower_bound: u64,
    /// `(loaded_elements − comm_lower_bound) / comm_lower_bound` — the
    /// certified element-domain optimality gap of this run (0.0 when the
    /// floor is zero).
    pub optimality_gap: f64,
    /// Output of the functional simulation (present in functional mode).
    pub output: Option<Vec<f32>>,
    /// Max |output - reference| from the functional check (if run).
    pub max_abs_error: Option<f32>,
}

impl SimReport {
    /// An empty report for a named strategy (sequential until the engine
    /// says otherwise).
    pub fn new(strategy_name: String) -> Self {
        SimReport {
            strategy_name,
            steps: Vec::new(),
            totals: StrategyCost::default(),
            duration: 0,
            sequential_duration: 0,
            overlap: OverlapMode::Sequential,
            dma_busy: 0,
            compute_busy: 0,
            dma_busy_per: Vec::new(),
            compute_busy_per: Vec::new(),
            peak_occupancy: 0,
            fault_retries: 0,
            mem_shrink_events: 0,
            wcet_bound: None,
            comm_lower_bound: 0,
            optimality_gap: 0.0,
            output: None,
            max_abs_error: None,
        }
    }

    /// Append one step's record, keeping the sequential aggregates in sync
    /// (the engine overrides `duration` with the makespan in
    /// double-buffered mode).
    pub fn push_step(&mut self, rec: StepRecord) {
        self.totals.push(&rec.cost);
        self.duration += rec.duration;
        self.sequential_duration += rec.duration;
        self.peak_occupancy = self.peak_occupancy.max(rec.occupancy);
        self.steps.push(rec);
    }

    /// Transfer cycles hidden behind compute by the overlapped timeline
    /// (0 in sequential mode by construction).
    pub fn hidden_cycles(&self) -> u64 {
        self.sequential_duration - self.duration
    }

    /// Number of compute steps `n` (flush and housekeeping excluded).
    pub fn n_compute_steps(&self) -> u64 {
        self.totals.n_compute_steps
    }

    /// `Σ |I_i^slice|` in elements — the bandwidth term of Eq. 15.
    pub fn total_loaded(&self) -> u64 {
        self.totals.total.loaded_elements
    }

    /// Did the functional check pass within `tol`?
    pub fn functional_ok(&self, tol: f32) -> Option<bool> {
        self.max_abs_error.map(|e| e <= tol)
    }

    /// Serialize (without the raw output tensor) for trace files.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("strategy", self.strategy_name.as_str())
            .set("duration", self.duration)
            .set("sequential_duration", self.sequential_duration)
            .set("overlap", self.overlap.as_str())
            .set("dma_busy", self.dma_busy)
            .set("compute_busy", self.compute_busy)
            .set(
                "dma_busy_per",
                Json::Arr(self.dma_busy_per.iter().map(|&v| v.into()).collect()),
            )
            .set(
                "compute_busy_per",
                Json::Arr(self.compute_busy_per.iter().map(|&v| v.into()).collect()),
            )
            .set("loaded_elements", self.total_loaded())
            .set("written_elements", self.totals.total.written_elements)
            .set("macs", self.totals.total.macs)
            .set("n_steps", self.totals.n_steps)
            .set("n_compute_steps", self.totals.n_compute_steps)
            .set("peak_occupancy", self.peak_occupancy)
            .set("comm_lower_bound", self.comm_lower_bound)
            .set("optimality_gap", self.optimality_gap);
        if let Some(wcet) = self.wcet_bound {
            o.set("fault_retries", self.fault_retries)
                .set("mem_shrink_events", self.mem_shrink_events)
                .set("wcet_bound", wcet);
        }
        if let Some(err) = self.max_abs_error {
            o.set("max_abs_error", err as f64);
        }
        let steps: Vec<Json> = self
            .steps
            .iter()
            .map(|s| {
                let mut so = Json::obj();
                so.set("index", s.index)
                    .set("loaded", s.cost.loaded_elements)
                    .set("written", s.cost.written_elements)
                    .set("macs", s.cost.macs)
                    .set("duration", s.duration)
                    .set("occupancy", s.occupancy)
                    .set("resident_input", s.resident_input_elements)
                    .set("group_len", s.group_len);
                if let Some(t) = &s.timing {
                    so.set("load_channel", t.load_channel)
                        .set("write_channel", t.write_channel)
                        .set("compute_unit", t.compute_unit);
                }
                so
            })
            .collect();
        o.set("steps", Json::Arr(steps));
        o
    }
}

/// Compact one-line summary used by the CLI and examples. In
/// double-buffered mode it reports the makespan plus the transfer cycles
/// hidden behind compute.
pub fn summary_line(report: &SimReport, acc: &Accelerator) -> String {
    let mut line = format!(
        "{:<24} δ={:>8} cycles  (loads {:>7} el × t_l={} | writes {:>6} el × t_w={} | {:>5} steps × t_acc={})  peak mem {:>7} el",
        report.strategy_name,
        report.duration,
        report.total_loaded(),
        acc.t_l,
        report.totals.total.written_elements,
        acc.t_w,
        report.n_compute_steps(),
        acc.t_acc,
        report.peak_occupancy,
    );
    if report.overlap == OverlapMode::DoubleBuffered {
        line.push_str(&format!(
            "  [double-buffered: sequential δ={} | hidden {} cycles | dma busy {} | compute busy {}]",
            report.sequential_duration,
            report.hidden_cycles(),
            report.dma_busy,
            report.compute_busy,
        ));
        if report.dma_busy_per.len() > 1 || report.compute_busy_per.len() > 1 {
            line.push_str(&format!(
                "  [per-resource busy: dma {:?} | compute {:?}]",
                report.dma_busy_per, report.compute_busy_per,
            ));
        }
    }
    if let Some(wcet) = report.wcet_bound {
        line.push_str(&format!(
            "  [faults: {} retries | {} shrink events | WCET({}) = {} cycles]",
            report.fault_retries,
            report.mem_shrink_events,
            report.fault_retries,
            wcet,
        ));
    }
    if report.comm_lower_bound > 0 {
        line.push_str(&format!(
            "  [certify: load floor {} el | gap {:.4}]",
            report.comm_lower_bound, report.optimality_gap,
        ));
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_accumulate() {
        let mut r = SimReport::new("test".into());
        r.push_step(StepRecord {
            index: 0,
            cost: StepCost { loaded_elements: 10, written_elements: 0, computed: true, macs: 5 },
            duration: 11,
            occupancy: 30,
            resident_input_elements: 10,
            group_len: 2,
            timing: None,
        });
        r.push_step(StepRecord {
            index: 1,
            cost: StepCost { loaded_elements: 4, written_elements: 2, computed: true, macs: 5 },
            duration: 5,
            occupancy: 40,
            resident_input_elements: 8,
            group_len: 2,
            timing: None,
        });
        assert_eq!(r.duration, 16);
        assert_eq!(r.sequential_duration, 16);
        assert_eq!(r.hidden_cycles(), 0);
        assert_eq!(r.total_loaded(), 14);
        assert_eq!(r.peak_occupancy, 40);
        assert_eq!(r.n_compute_steps(), 2);
        let j = r.to_json();
        assert_eq!(j.get("duration").unwrap().as_u64(), Some(16));
        assert_eq!(j.get("steps").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn functional_ok_requires_error_bound() {
        let mut r = SimReport::new("f".into());
        assert_eq!(r.functional_ok(1e-5), None);
        r.max_abs_error = Some(1e-6);
        assert_eq!(r.functional_ok(1e-5), Some(true));
        r.max_abs_error = Some(1e-3);
        assert_eq!(r.functional_ok(1e-5), Some(false));
    }
}

//! The simulation engine: §6's orchestration loop.

use crate::conv::{ConvLayer, PatchId};
use crate::platform::{FaultModel, MemoryState, OverlapMode, Platform};
use crate::sim::{ComputeBackend, SimReport, StepRecord};
use crate::step::{self, OverlapTimeline, Step, StepError};
use crate::strategy::GroupedStrategy;

/// Simulation failure.
#[derive(Debug, Clone, PartialEq)]
pub enum SimError {
    /// The DRAM cannot hold the layer (violates the §2.1 assumption).
    DramTooSmall,
    /// A step violated the semantics / assumptions.
    Step { index: usize, error: StepError },
    /// Functional mode: wrong tensor sizes supplied.
    BadTensors(String),
    /// Functional mode: the compute backend failed.
    Backend(String),
    /// Functional mode: a value needed by the compute was not on chip.
    /// (Defence in depth — the semantics check should catch this first.)
    ValueNotResident { pixel: u32 },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}
impl std::error::Error for SimError {}

/// The simulator: a layer bound to a platform.
#[derive(Debug, Clone)]
pub struct Simulator {
    /// The layer being offloaded.
    pub layer: ConvLayer,
    /// The platform (accelerator + DRAM) executing it.
    pub platform: Platform,
    /// Enforce the §2.3 assumptions during stepping (default true).
    pub strict: bool,
    /// Optional deterministic fault injection (None = fault-free; an
    /// inactive model is treated identically to None).
    pub faults: Option<FaultModel>,
    /// Number of images the strategy processes back to back (≥ 1; the cost
    /// model only — kernels stay resident across images, so images after the
    /// first skip the kernel reload, and on a multi-resource accelerator
    /// consecutive images pipeline onto free units).
    pub batch: usize,
}

impl Simulator {
    /// A strict-mode, fault-free, single-image simulator for `layer` on
    /// `platform`.
    pub fn new(layer: ConvLayer, platform: Platform) -> Self {
        Simulator { layer, platform, strict: true, faults: None, batch: 1 }
    }

    /// The same simulator batched over `batch` images (builder-style;
    /// clamped to ≥ 1). The strategy's step stream replays once per image:
    /// the terminal flush leaves on-chip memory empty, so every image sees
    /// identical residency, and only step 0's kernel load drops out after
    /// the first image. Logical mode only — [`Simulator::run_functional`]
    /// rejects batches, since it moves one image's real values.
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }

    /// The same simulator with a [`FaultModel`] injected (builder-style).
    /// Faults perturb *timing only* — retries, jitter, and the shrink-driven
    /// prefetch fallback; the functional semantics and the strict §2.3
    /// checks are unchanged, because a shrunk memory degrades performance,
    /// not correctness, for a strategy validated against the full budget.
    pub fn with_faults(mut self, faults: FaultModel) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Logical simulation: execute the strategy, tracking sets and costs
    /// only. Runs at millions of steps per second; used by the optimizer's
    /// objective evaluation and the figure sweeps.
    ///
    /// The report's `duration` follows the accelerator's
    /// [`crate::platform::OverlapMode`]: the Definition-3 sum when
    /// sequential, the §3.7 critical-path makespan when double-buffered
    /// (with per-step [`crate::step::StepTiming`] records attached).
    ///
    /// # Examples
    ///
    /// ```
    /// use convoffload::prelude::*;
    /// use convoffload::strategy;
    ///
    /// let layer = ConvLayer::new(1, 8, 8, 3, 3, 1, 1, 1).unwrap();
    /// let acc = Accelerator::for_group_size(&layer, 2);
    /// let report = Simulator::new(layer, Platform::new(acc))
    ///     .run(&strategy::zigzag(&layer, 2))
    ///     .unwrap();
    /// // every distinct input pixel loads at least once
    /// assert!(report.total_loaded() >= 64);
    /// assert_eq!(report.duration, report.sequential_duration);
    /// ```
    pub fn run(&self, strategy: &GroupedStrategy) -> Result<SimReport, SimError> {
        if !self.platform.dram_fits(&self.layer) {
            return Err(SimError::DramTooSmall);
        }
        let steps = strategy.compile(&self.layer);
        let mut mem = MemoryState::initial(&self.layer);
        let mut report = SimReport::new(strategy.name.clone());
        self.execute_steps(&steps, &mut mem, &mut report, None)?;
        Ok(report)
    }

    /// Functional simulation: additionally moves real values through the
    /// modelled memories, computes each step on `backend`, assembles the
    /// output in DRAM and compares against the whole-layer reference
    /// convolution (§6's “functional simulation that can assess if the
    /// result of the step-by-step convolution is correct”).
    pub fn run_functional(
        &self,
        strategy: &GroupedStrategy,
        input: &[f32],
        kernels: &[f32],
        backend: &mut dyn ComputeBackend,
    ) -> Result<SimReport, SimError> {
        if input.len() != self.layer.input_dims().len() {
            return Err(SimError::BadTensors(format!(
                "input has {} elements, expected {}",
                input.len(),
                self.layer.input_dims().len()
            )));
        }
        if kernels.len() != self.layer.kernel_elements() {
            return Err(SimError::BadTensors(format!(
                "kernels have {} elements, expected {}",
                kernels.len(),
                self.layer.kernel_elements()
            )));
        }
        if self.batch > 1 {
            return Err(SimError::BadTensors(format!(
                "functional mode simulates one image, not a batch of {}",
                self.batch
            )));
        }
        if !self.platform.dram_fits(&self.layer) {
            return Err(SimError::DramTooSmall);
        }

        let steps = strategy.compile(&self.layer);
        let mut mem = MemoryState::initial(&self.layer);
        let mut report = SimReport::new(strategy.name.clone());
        let mut func = FunctionalState::new(&self.layer, input, kernels);
        self.execute_steps(&steps, &mut mem, &mut report, Some((&mut func, backend)))?;

        // Compare against the reference convolution.
        let reference =
            crate::conv::reference::conv2d(&self.layer, input, kernels);
        let max_err = func
            .dram_output
            .iter()
            .zip(&reference)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        report.output = Some(func.dram_output);
        report.max_abs_error = Some(max_err);
        Ok(report)
    }

    fn execute_steps(
        &self,
        steps: &[Step],
        mem: &mut MemoryState,
        report: &mut SimReport,
        mut functional: Option<(&mut FunctionalState, &mut dyn ComputeBackend)>,
    ) -> Result<(), SimError> {
        let acc = &self.platform.accelerator;
        report.overlap = acc.overlap;
        // Multi-resource schedule (k DMA channels × m compute units; 1×1
        // reproduces the §3.7 two-resource recurrence bit-exactly), built
        // alongside the sequential accounting when the accelerator overlaps
        // DMA with compute.
        let mut timeline = (acc.overlap == OverlapMode::DoubleBuffered)
            .then(|| OverlapTimeline::with_resources(acc.dma_channels, acc.compute_units));
        // Occupancy at the end of the previous step — the left-hand side of
        // the §3.7 double-buffer residency condition.
        let mut prev_occupancy = 0u64;
        // Fault state: the effective memory budget shrinks (stickily) as
        // MemoryShrink events fire; an inactive model injects nothing.
        let fm = self.faults.filter(FaultModel::is_active);
        let retry_penalty = fm.map_or(0, |m| m.retry_penalty);
        let mut effective_mem = acc.size_mem;
        let mut total_retries = 0u64;
        let mut shrink_events = 0u64;
        let mut max_load_cycles = 0u64;
        // Busy totals accumulate the *effective* (post-fault) phases so the
        // resource floor `duration ≥ max(dma_busy, compute_busy)` stays a
        // theorem under injection; with no faults these sums are bit-equal
        // to the totals-derived values used before fault support.
        let mut dma_busy = 0u64;
        let mut compute_busy = 0u64;
        for (i, st) in steps.iter().enumerate() {
            // Value movement must mirror the action order: frees/writes
            // before loads, compute last. Writes need the *pre-step* values.
            if let Some((func, backend)) = functional.as_mut() {
                func.apply_step(&self.layer, st, *backend)?;
            }
            let outcome = step::apply(&self.layer, acc, mem, st, self.strict)
                .map_err(|error| SimError::Step { index: i, error })?;
            let fx = fm
                .map(|m| {
                    m.step_faults(
                        i as u64,
                        outcome.cost.loaded_elements,
                        outcome.cost.written_elements,
                        outcome.cost.computed,
                    )
                })
                .unwrap_or_default();
            if fx.shrink {
                shrink_events += 1;
                effective_mem =
                    effective_mem.saturating_sub(fm.expect("shrink implies model").shrink_elements);
            }
            total_retries += fx.load_retries as u64;
            max_load_cycles = max_load_cycles.max(outcome.cost.load_cycles(acc));
            let load_cycles = outcome.cost.faulted_load_cycles(acc, &fx, retry_penalty);
            let write_cycles = outcome.cost.written_elements * acc.t_w;
            let compute_cycles = outcome.cost.faulted_compute_cycles(acc, &fx);
            dma_busy += load_cycles + write_cycles;
            compute_busy += compute_cycles;
            let timing = timeline.as_mut().map(|t| {
                // Residency condition against the *effective* (shrunk)
                // budget: this step's incoming elements must fit alongside
                // the previous step's still-live working set, or the load
                // serializes behind the previous compute.
                let can_prefetch =
                    prev_occupancy + outcome.cost.loaded_elements <= effective_mem;
                t.push(load_cycles, write_cycles, compute_cycles, can_prefetch)
            });
            prev_occupancy = outcome.occupancy;
            report.push_step(StepRecord {
                index: i,
                duration: outcome.cost.faulted_duration(acc, &fx, retry_penalty),
                cost: outcome.cost,
                occupancy: outcome.occupancy,
                resident_input_elements: (mem.inp.len() * self.layer.c_in) as u64,
                group_len: st.group.len(),
                timing,
            });
        }
        // Images 1.. of a batch replay the recorded step shapes: the flush
        // left on-chip memory empty, so residency repeats verbatim except
        // that step 0 keeps the already-resident kernels. Fault draws use
        // the *global* step index `b·n_steps + i`, so a batched trace is as
        // replayable as a single-image one.
        let n_steps = steps.len();
        if self.batch > 1 {
            let base: Vec<StepRecord> = report.steps.clone();
            let kernel_elements = self.layer.kernel_elements() as u64;
            for b in 1..self.batch {
                if let Some(t) = timeline.as_mut() {
                    t.begin_image();
                }
                for (i, rec0) in base.iter().enumerate() {
                    let mut cost = rec0.cost;
                    if i == 0 {
                        debug_assert!(cost.loaded_elements >= kernel_elements);
                        cost.loaded_elements =
                            cost.loaded_elements.saturating_sub(kernel_elements);
                    }
                    let index = b * n_steps + i;
                    let fx = fm
                        .map(|m| {
                            m.step_faults(
                                index as u64,
                                cost.loaded_elements,
                                cost.written_elements,
                                cost.computed,
                            )
                        })
                        .unwrap_or_default();
                    if fx.shrink {
                        shrink_events += 1;
                        effective_mem = effective_mem.saturating_sub(
                            fm.expect("shrink implies model").shrink_elements,
                        );
                    }
                    total_retries += fx.load_retries as u64;
                    max_load_cycles = max_load_cycles.max(cost.load_cycles(acc));
                    let load_cycles = cost.faulted_load_cycles(acc, &fx, retry_penalty);
                    let write_cycles = cost.written_elements * acc.t_w;
                    let compute_cycles = cost.faulted_compute_cycles(acc, &fx);
                    dma_busy += load_cycles + write_cycles;
                    compute_busy += compute_cycles;
                    let timing = timeline.as_mut().map(|t| {
                        let can_prefetch =
                            prev_occupancy + cost.loaded_elements <= effective_mem;
                        t.push(load_cycles, write_cycles, compute_cycles, can_prefetch)
                    });
                    prev_occupancy = rec0.occupancy;
                    report.push_step(StepRecord {
                        index,
                        duration: cost.faulted_duration(acc, &fx, retry_penalty),
                        cost,
                        occupancy: rec0.occupancy,
                        resident_input_elements: rec0.resident_input_elements,
                        group_len: rec0.group_len,
                        timing,
                    });
                }
            }
        }
        // Resource busy totals hold in either mode; the double-buffered
        // duration is the critical-path makespan instead of the sum.
        report.dma_busy = dma_busy;
        report.compute_busy = compute_busy;
        if let Some(t) = &timeline {
            debug_assert_eq!(t.dma_busy(), report.dma_busy);
            debug_assert_eq!(t.compute_busy(), report.compute_busy);
            report.duration = t.makespan();
        }
        // Per-resource busy splits: real assignments from the timeline when
        // one exists, otherwise the single-resource totals (sequential mode
        // has exactly one DMA channel and one compute unit by construction).
        (report.dma_busy_per, report.compute_busy_per) = match &timeline {
            Some(t) => (t.dma_busy_per().to_vec(), t.compute_busy_per().to_vec()),
            None => (vec![dma_busy], vec![compute_busy]),
        };
        if let Some(m) = fm {
            report.fault_retries = total_retries;
            report.mem_shrink_events = shrink_events;
            // The analytic k-fault worst case at the trace's own k — the
            // bound every simulated trace with ≤ k retries must respect.
            report.wcet_bound = Some(m.makespan_under_k_faults(
                report.totals.duration(acc),
                report.totals.n_steps,
                report.totals.n_compute_steps,
                max_load_cycles,
                total_retries,
            ));
            debug_assert!(
                report.wcet_bound.unwrap() >= report.duration,
                "WCET bound below a simulated trace"
            );
        }
        // Certification floor (read-only w.r.t. the run itself): the
        // element-domain load floor for this batched trace. Kernels load
        // once per run, inputs at best once per image; fault effects are
        // cycles-only, so the floor holds for fault-injected runs too.
        let lb = crate::planner::certify::comm_lower_bound(&self.layer, acc);
        report.comm_lower_bound =
            self.batch as u64 * lb.input_element_floor + lb.kernel_elements;
        report.optimality_gap = crate::planner::certify::optimality_gap(
            report.totals.total.loaded_elements,
            report.comm_lower_bound,
        );
        Ok(())
    }
}

/// Value state for the functional simulation: the on-chip stores and the
/// DRAM output buffer.
struct FunctionalState<'a> {
    /// DRAM input (read-only).
    dram_input: &'a [f32],
    /// DRAM kernels (read-only).
    dram_kernels: &'a [f32],
    /// DRAM output being assembled by write-backs: `[C_out, H_out, W_out]`.
    dram_output: Vec<f32>,
    /// On-chip input values, indexed `[channel][pixel]`; `NaN` = absent.
    onchip_input: Vec<f32>,
    /// On-chip kernel matrix `[D, N]` (present iff kernels resident).
    onchip_kernels: Vec<f32>,
    n_resident_kernels: usize,
    /// On-chip computed outputs per patch: `[N]` per entry.
    onchip_outputs: Vec<Option<Vec<f32>>>,
}

impl<'a> FunctionalState<'a> {
    fn new(layer: &ConvLayer, input: &'a [f32], kernels: &'a [f32]) -> Self {
        FunctionalState {
            dram_input: input,
            dram_kernels: kernels,
            dram_output: vec![f32::NAN; layer.output_dims().len()],
            onchip_input: vec![f32::NAN; layer.input_dims().len()],
            onchip_kernels: Vec::new(),
            n_resident_kernels: 0,
            onchip_outputs: vec![None; layer.n_patches()],
        }
    }

    fn apply_step(
        &mut self,
        layer: &ConvLayer,
        st: &Step,
        backend: &mut dyn ComputeBackend,
    ) -> Result<(), SimError> {
        let (h_in, w_in) = (layer.h_in, layer.w_in);
        let px_per_ch = h_in * w_in;

        // a_1: free inputs (all channels of each freed pixel).
        for px in st.free_inp.iter() {
            for c in 0..layer.c_in {
                self.onchip_input[c * px_per_ch + px as usize] = f32::NAN;
            }
        }
        // a_2: free kernels.
        if !st.free_ker.is_empty() {
            self.n_resident_kernels -= st.free_ker.len();
            if self.n_resident_kernels == 0 {
                self.onchip_kernels.clear();
            }
        }
        // a_3: write back outputs.
        let (h_out, w_out) = (layer.h_out(), layer.w_out());
        for p in st.write.iter() {
            let vals = self.onchip_outputs[p as usize]
                .take()
                .ok_or(SimError::ValueNotResident { pixel: p })?;
            let patch = layer.patch(p);
            for (ch, &v) in vals.iter().enumerate() {
                self.dram_output[(ch * h_out + patch.i) * w_out + patch.j] = v;
            }
        }
        // a_4: load inputs from DRAM.
        for px in st.load_inp.iter() {
            for c in 0..layer.c_in {
                let idx = c * px_per_ch + px as usize;
                self.onchip_input[idx] = self.dram_input[idx];
            }
        }
        // a_5: load kernels (S1 loads all at once; model incremental too).
        if !st.load_ker.is_empty() {
            self.n_resident_kernels += st.load_ker.len();
            if self.n_resident_kernels == layer.n_kernels {
                self.onchip_kernels = crate::conv::reference::kernel_matrix(
                    layer,
                    self.dram_kernels,
                );
            }
        }
        // a_6: compute on the backend from *on-chip* data only.
        if !st.group.is_empty() {
            let d = layer.im2col_width();
            let mut pm = vec![0f32; st.group.len() * d];
            for (r, &p) in st.group.iter().enumerate() {
                self.gather_patch(layer, p, &mut pm[r * d..(r + 1) * d])?;
            }
            let out = backend
                .step_compute(layer, &pm, &self.onchip_kernels, st.group.len())
                .map_err(SimError::Backend)?;
            for (r, &p) in st.group.iter().enumerate() {
                self.onchip_outputs[p as usize] = Some(
                    out[r * layer.n_kernels..(r + 1) * layer.n_kernels].to_vec(),
                );
            }
        }
        Ok(())
    }

    /// im2col gather of one patch from the **on-chip** store (dilated taps
    /// at `h·d_h` / `w·d_w`; the row spans all `C_in` channels — see
    /// [`crate::conv::reference::im2col_row`]).
    fn gather_patch(
        &self,
        layer: &ConvLayer,
        patch: PatchId,
        out: &mut [f32],
    ) -> Result<(), SimError> {
        let p = layer.patch(patch);
        let (h_in, w_in) = (layer.h_in, layer.w_in);
        let px_per_ch = h_in * w_in;
        let mut idx = 0;
        for c in 0..layer.c_in {
            for h in 0..layer.h_k {
                for w in 0..layer.w_k {
                    let py = (p.i * layer.s_h + h * layer.d_h) * w_in
                        + p.j * layer.s_w
                        + w * layer.d_w;
                    let v = self.onchip_input[c * px_per_ch + py];
                    if v.is_nan() {
                        return Err(SimError::ValueNotResident { pixel: py as u32 });
                    }
                    out[idx] = v;
                    idx += 1;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::reference;
    use crate::platform::Accelerator;
    use crate::sim::RustOracleBackend;
    use crate::strategy;

    fn setup(group: usize) -> (ConvLayer, Simulator) {
        let l = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
        let acc = Accelerator::for_group_size(&l, group);
        (l, Simulator::new(l, Platform::new(acc)))
    }

    #[test]
    fn logical_run_produces_report() {
        let (l, sim) = setup(2);
        let s = strategy::row_by_row(&l, 2);
        let r = sim.run(&s).unwrap();
        assert_eq!(r.n_compute_steps() as usize, s.n_steps());
        assert_eq!(r.steps.len(), s.n_steps() + 1); // + flush
        assert!(r.duration > 0);
        // all 50 input elements loaded at least once
        assert!(r.total_loaded() >= 50);
    }

    #[test]
    fn functional_run_matches_reference() {
        let (l, _sim) = setup(2);
        let input = reference::synth_tensor(l.input_dims().len(), 1);
        let kernels = reference::synth_tensor(l.kernel_elements(), 2);
        for s in [
            strategy::s1_baseline(&l),
            strategy::row_by_row(&l, 2),
            strategy::zigzag(&l, 2),
        ] {
            // s1-baseline needs group-size-1 accelerator; reuse a roomy one
            let acc = Accelerator::for_group_size(&l, 2);
            let sim = Simulator::new(l, Platform::new(acc));
            let mut backend = RustOracleBackend;
            let r = sim
                .run_functional(&s, &input, &kernels, &mut backend)
                .unwrap();
            assert_eq!(r.functional_ok(1e-5), Some(true), "{}", s.name);
            // every output value was written (no NaN left)
            assert!(r.output.unwrap().iter().all(|v| !v.is_nan()));
        }
    }

    /// The functional simulation must reproduce the reference convolution
    /// for dilated and grouped layers too (stepwise gather + zero-expanded
    /// kernel matrix).
    #[test]
    fn functional_run_matches_reference_generalized() {
        let layers = [
            ConvLayer::new(2, 9, 9, 3, 3, 2, 1, 1)
                .unwrap()
                .with_dilation(2, 2)
                .unwrap(),
            ConvLayer::new(4, 7, 7, 3, 3, 4, 1, 1)
                .unwrap()
                .with_groups(4)
                .unwrap(),
            ConvLayer::new(4, 9, 9, 3, 3, 8, 2, 2)
                .unwrap()
                .with_dilation(2, 2)
                .unwrap()
                .with_groups(2)
                .unwrap(),
        ];
        for l in layers {
            let acc = Accelerator::for_group_size(&l, 2);
            let sim = Simulator::new(l, Platform::new(acc));
            let input = reference::synth_tensor(l.input_dims().len(), 5);
            let kernels = reference::synth_tensor(l.kernel_elements(), 6);
            let mut backend = RustOracleBackend;
            let r = sim
                .run_functional(&strategy::zigzag(&l, 2), &input, &kernels, &mut backend)
                .unwrap();
            assert_eq!(r.functional_ok(1e-4), Some(true), "{l}");
            assert!(r.output.unwrap().iter().all(|v| !v.is_nan()), "{l}");
        }
    }

    #[test]
    fn functional_rejects_bad_tensor_sizes() {
        let (l, sim) = setup(2);
        let s = strategy::row_by_row(&l, 2);
        let mut b = RustOracleBackend;
        assert!(matches!(
            sim.run_functional(&s, &[0.0; 3], &[0.0; 36], &mut b),
            Err(SimError::BadTensors(_))
        ));
        assert!(matches!(
            sim.run_functional(&s, &[0.0; 50], &[0.0; 5], &mut b),
            Err(SimError::BadTensors(_))
        ));
    }

    #[test]
    fn oversized_group_fails_in_strict_mode() {
        let (l, sim) = setup(1); // accelerator sized for 1 patch / step
        let s = strategy::row_by_row(&l, 3);
        match sim.run(&s) {
            Err(SimError::Step { .. }) => {}
            other => panic!("expected step error, got {other:?}"),
        }
    }

    /// The hand-computed overlap regression (mirrored in the Python oracle,
    /// `test_oracle_sim.py::TestOverlappedTimeline`): a single-row scan
    /// whose three steps have fully hand-checkable phase placements.
    ///
    /// Layer 1×3×12, 3×3 kernel → one row of 10 patches; groups of 4 give
    /// steps of (18, 12, 6) loaded pixels + 9 kernel elements at step 1,
    /// write-backs of (0, 4, 4) + flush 2 at `t_w = 1`, `t_acc = 4`.
    /// Sequential δ = 31 + 20 + 14 + 2 = 67.
    #[test]
    fn double_buffered_hand_computed_makespan() {
        let l = ConvLayer::new(1, 3, 12, 3, 3, 1, 1, 1).unwrap();
        let s = strategy::row_by_row(&l, 4);
        let base = Accelerator {
            t_acc: 4,
            t_w: 1,
            ..Accelerator::paper_eval(36, 64)
        };

        // Sequential reference.
        let seq = Simulator::new(l, Platform::new(base)).run(&s).unwrap();
        assert_eq!(seq.duration, 67);
        assert_eq!(seq.sequential_duration, 67);
        assert_eq!(seq.hidden_cycles(), 0);
        assert!(seq.steps.iter().all(|st| st.timing.is_none()));

        // Roomy double buffer (size_mem 64): every load prefetches; the
        // makespan is DMA-bound at 55 cycles — all 12 compute cycles hidden.
        let db = base.with_overlap(OverlapMode::DoubleBuffered);
        let r = Simulator::new(l, Platform::new(db)).run(&s).unwrap();
        assert_eq!(r.sequential_duration, 67);
        assert_eq!(r.duration, 55);
        assert_eq!(r.hidden_cycles(), 12);
        assert_eq!(r.dma_busy, 55);
        assert_eq!(r.compute_busy, 12);
        let t1 = r.steps[0].timing.unwrap();
        assert_eq!((t1.load_start, t1.load_end), (0, 27));
        assert_eq!((t1.compute_start, t1.compute_end), (27, 31));
        let t2 = r.steps[1].timing.unwrap();
        assert!(t2.prefetched);
        assert_eq!((t2.load_start, t2.load_end), (27, 39));
        assert_eq!((t2.write_start, t2.write_end), (39, 43));
        assert_eq!((t2.compute_start, t2.compute_end), (39, 43));
        let t3 = r.steps[2].timing.unwrap();
        assert_eq!((t3.load_start, t3.load_end), (43, 49));
        assert_eq!((t3.compute_start, t3.compute_end), (49, 53));
        let tf = r.steps[3].timing.unwrap();
        assert_eq!((tf.write_start, tf.write_end), (53, 55));

        // Tight double buffer (size_mem 40): step 2's incoming 12 elements
        // do not fit beside step 1's 31-element working set, so its load
        // serializes behind compute 1 — makespan 59, still ≤ sequential.
        let tight = Accelerator { size_mem: 40, ..db };
        let r = Simulator::new(l, Platform::new(tight)).run(&s).unwrap();
        assert_eq!(r.duration, 59);
        assert_eq!(r.hidden_cycles(), 8);
        let t2 = r.steps[1].timing.unwrap();
        assert!(!t2.prefetched);
        assert_eq!((t2.load_start, t2.load_end), (31, 43));
        let t3 = r.steps[2].timing.unwrap();
        assert!(t3.prefetched, "step 3's smaller load fits again");
    }

    /// On every preset-sized setup the overlapped makespan obeys its two
    /// analytic bounds against the sequential run.
    #[test]
    fn double_buffered_bounds_vs_sequential() {
        for (l, g) in [
            (ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap(), 2usize),
            (ConvLayer::new(1, 8, 8, 3, 3, 1, 1, 1).unwrap(), 4),
            (
                ConvLayer::new(4, 9, 9, 3, 3, 8, 2, 2)
                    .unwrap()
                    .with_dilation(2, 2)
                    .unwrap()
                    .with_groups(2)
                    .unwrap(),
                2,
            ),
        ] {
            let base = Accelerator { t_w: 1, ..Accelerator::for_group_size(&l, g) };
            for s in [strategy::row_by_row(&l, g), strategy::zigzag(&l, g)] {
                let seq = Simulator::new(l, Platform::new(base)).run(&s).unwrap();
                let db = base.with_overlap(OverlapMode::DoubleBuffered);
                let ovl = Simulator::new(l, Platform::new(db)).run(&s).unwrap();
                assert_eq!(ovl.sequential_duration, seq.duration, "{} {l}", s.name);
                assert!(ovl.duration <= seq.duration, "{} {l}", s.name);
                assert!(
                    ovl.duration >= ovl.dma_busy.max(ovl.compute_busy),
                    "{} {l}",
                    s.name
                );
            }
        }
    }

    /// Fault injection contract on the hand-computed chain: the zero model
    /// (and an attached-but-inactive model) is bit-identical to the
    /// fault-free run; an active model inflates the makespan, never deflates
    /// it, stays deterministic, and respects its own WCET bound.
    #[test]
    fn fault_injection_identity_and_inflation() {
        use crate::platform::FaultModel;
        let l = ConvLayer::new(1, 3, 12, 3, 3, 1, 1, 1).unwrap();
        let s = strategy::row_by_row(&l, 4);
        let base = Accelerator { t_acc: 4, t_w: 1, ..Accelerator::paper_eval(36, 64) };
        for acc in [base, base.with_overlap(OverlapMode::DoubleBuffered)] {
            let clean = Simulator::new(l, Platform::new(acc)).run(&s).unwrap();
            let zero = Simulator::new(l, Platform::new(acc))
                .with_faults(FaultModel::none().with_seed(99))
                .run(&s)
                .unwrap();
            assert_eq!(zero.duration, clean.duration, "{}", acc.overlap.as_str());
            assert_eq!(zero.sequential_duration, clean.sequential_duration);
            assert_eq!(zero.dma_busy, clean.dma_busy);
            assert_eq!(zero.compute_busy, clean.compute_busy);
            assert_eq!(zero.wcet_bound, None, "inactive model reports no bound");

            let m = FaultModel {
                seed: 7,
                dma_fail_rate: 0.5,
                max_retries: 3,
                retry_penalty: 5,
                dma_jitter: 4,
                t_acc_jitter: 2,
                shrink_rate: 0.3,
                shrink_elements: 16,
            };
            let a = Simulator::new(l, Platform::new(acc)).with_faults(m).run(&s).unwrap();
            let b = Simulator::new(l, Platform::new(acc)).with_faults(m).run(&s).unwrap();
            assert_eq!(a.duration, b.duration, "same seed, same trace");
            assert_eq!(a.fault_retries, b.fault_retries);
            assert_eq!(a.mem_shrink_events, b.mem_shrink_events);
            assert!(a.duration >= clean.duration, "faults never speed a run up");
            assert!(a.fault_retries > 0, "rate 0.5 over 4 steps must retry");
            let wcet = a.wcet_bound.expect("active model reports the bound");
            assert!(wcet >= a.duration, "bound must dominate the trace");
            // A different seed gives a different (but still bounded) trace.
            let c = Simulator::new(l, Platform::new(acc))
                .with_faults(m.with_seed(8))
                .run(&s)
                .unwrap();
            assert!(c.wcet_bound.unwrap() >= c.duration);
        }
    }

    /// A shrink-only model leaves sequential runs untouched (shrink affects
    /// only the residency condition) but forces the tight double buffer to
    /// serialize more — duration rises toward, never past, the sequential
    /// sum.
    #[test]
    fn shrink_only_faults_degrade_overlap_not_sequential() {
        use crate::platform::FaultModel;
        let l = ConvLayer::new(1, 3, 12, 3, 3, 1, 1, 1).unwrap();
        let s = strategy::row_by_row(&l, 4);
        let m = FaultModel {
            seed: 3,
            shrink_rate: 1.0, // every step shrinks
            shrink_elements: 20,
            ..FaultModel::none()
        };
        let seq = Accelerator { t_acc: 4, t_w: 1, ..Accelerator::paper_eval(36, 64) };
        let clean_seq = Simulator::new(l, Platform::new(seq)).run(&s).unwrap();
        let fault_seq =
            Simulator::new(l, Platform::new(seq)).with_faults(m).run(&s).unwrap();
        assert_eq!(fault_seq.duration, clean_seq.duration);
        assert!(fault_seq.mem_shrink_events > 0);

        let db = seq.with_overlap(OverlapMode::DoubleBuffered);
        let clean_db = Simulator::new(l, Platform::new(db)).run(&s).unwrap();
        let fault_db =
            Simulator::new(l, Platform::new(db)).with_faults(m).run(&s).unwrap();
        assert!(fault_db.duration >= clean_db.duration);
        assert!(fault_db.duration <= fault_db.sequential_duration);
        assert!(
            fault_db.steps.iter().filter(|st| st.timing.is_some_and(|t| t.prefetched)).count()
                < clean_db.steps.iter().filter(|st| st.timing.is_some_and(|t| t.prefetched)).count(),
            "an exhausted budget must deny prefetches the clean run allowed"
        );
    }

    /// Image batching: the flush leaves on-chip memory empty, so a batch of
    /// N replays the same step stream with step 0's kernel reload dropped —
    /// the sequential duration is affine in N, and the multi-resource double
    /// buffer pipelines consecutive images onto free units.
    #[test]
    fn batched_runs_are_affine_and_pipeline() {
        let l = ConvLayer::new(1, 3, 12, 3, 3, 1, 1, 1).unwrap();
        let s = strategy::row_by_row(&l, 4);
        let base = Accelerator { t_acc: 4, t_w: 1, ..Accelerator::paper_eval(36, 64) };
        let one = Simulator::new(l, Platform::new(base)).run(&s).unwrap();
        assert_eq!(one.duration, 67);
        let four =
            Simulator::new(l, Platform::new(base)).with_batch(4).run(&s).unwrap();
        let kernel_reload = l.kernel_elements() as u64 * base.t_l; // 9 cycles
        assert_eq!(four.sequential_duration, 4 * 67 - 3 * kernel_reload);
        assert_eq!(four.duration, four.sequential_duration);
        assert_eq!(four.steps.len(), 4 * one.steps.len());

        let db = base.with_overlap(OverlapMode::DoubleBuffered).with_channels(2, 2);
        let r = Simulator::new(l, Platform::new(db)).with_batch(4).run(&s).unwrap();
        assert_eq!(r.sequential_duration, four.sequential_duration);
        assert!(r.duration <= four.duration);
        assert!(r.duration >= r.dma_busy.div_ceil(2).max(r.compute_busy.div_ceil(2)));
        assert_eq!(r.dma_busy_per.len(), 2);
        assert_eq!(r.compute_busy_per.len(), 2);
        assert_eq!(r.dma_busy_per.iter().sum::<u64>(), r.dma_busy);
        assert_eq!(r.compute_busy_per.iter().sum::<u64>(), r.compute_busy);

        // Functional mode moves one image's real values: batches are logical.
        let input = reference::synth_tensor(l.input_dims().len(), 1);
        let kernels = reference::synth_tensor(l.kernel_elements(), 2);
        let mut backend = RustOracleBackend;
        assert!(matches!(
            Simulator::new(l, Platform::new(base))
                .with_batch(2)
                .run_functional(&s, &input, &kernels, &mut backend),
            Err(SimError::BadTensors(_))
        ));
    }

    #[test]
    fn example2_durations_row_vs_zigzag() {
        // Example 2 accounting in *elements*: step 2 of both strategies
        // loads 6 spatial pixels = 12 elements and writes the 2 patches of
        // step 1 = 4 output elements. (The paper's example counts spatial
        // pixels, i.e. divides by C_in = C_out = 2 — see EXPERIMENTS.md.)
        let (l, _) = setup(2);
        let acc = Accelerator { t_w: 1, ..Accelerator::for_group_size(&l, 2) };
        let sim = Simulator::new(l, Platform::new(acc));
        for s in [strategy::row_by_row(&l, 2), strategy::zigzag(&l, 2)] {
            let r = sim.run(&s).unwrap();
            let s2 = &r.steps[1];
            assert_eq!(s2.cost.loaded_elements, 12, "{}", s.name);
            assert_eq!(s2.cost.written_elements, 4, "{}", s.name);
            // δ(s_2) = 12·t_l + 4·t_w + t_acc = 17
            assert_eq!(s2.duration, 17, "{}", s.name);
        }
    }

    #[test]
    fn example2_memory_footprint_row_vs_zigzag() {
        // M_2^inp: Row-by-Row = 32 elements, ZigZag = 24 elements (paper's
        // Example 2 numbers ×C_in are 32 and 24 — these match exactly
        // because the paper states them in elements here).
        let (l, sim) = setup(2);
        let row = sim.run(&strategy::row_by_row(&l, 2)).unwrap();
        let zig = sim.run(&strategy::zigzag(&l, 2)).unwrap();
        assert_eq!(row.steps[1].resident_input_elements, 32);
        assert_eq!(zig.steps[1].resident_input_elements, 24);
    }
}

//! Compute backends for the functional simulation.
//!
//! The accelerator's `a_6` action — “compute the group of patches against all
//! kernels” — is abstracted behind [`ComputeBackend`] so the simulator can
//! run it either on the in-process Rust oracle or on the AOT-compiled XLA
//! executable through PJRT ([`crate::runtime::PjrtBackend`]). Both receive
//! the *im2col-gathered on-chip data only*, so a backend cannot cheat by
//! peeking at input values the strategy failed to load.

use crate::conv::ConvLayer;

/// A per-step compute engine.
pub trait ComputeBackend {
    /// Multiply `patches [rows, C_in·H_K·W_K]` (row-major) by
    /// `kernels [C_in·H_K·W_K, N]` (row-major), returning `[rows, N]`.
    ///
    /// `rows` is the group size of the step being executed.
    fn step_compute(
        &mut self,
        layer: &ConvLayer,
        patches: &[f32],
        kernel_matrix: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>, String>;

    /// Identifier for reports.
    fn name(&self) -> &str;
}

/// Backend selector used by CLI / examples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FunctionalBackend {
    /// Pure-Rust GEMM oracle (always available).
    RustOracle,
    /// AOT XLA executable via the PJRT CPU client (requires artifacts).
    Pjrt,
}

impl FunctionalBackend {
    /// Stable backend name (`rust-oracle`, `pjrt`).
    pub fn as_str(&self) -> &'static str {
        match self {
            FunctionalBackend::RustOracle => "rust-oracle",
            FunctionalBackend::Pjrt => "pjrt",
        }
    }

    /// Parse a backend name (accepts `rust`, `oracle`, `xla` aliases).
    pub fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "rust-oracle" | "rust" | "oracle" => Ok(FunctionalBackend::RustOracle),
            "pjrt" | "xla" => Ok(FunctionalBackend::Pjrt),
            other => Err(format!("unknown backend '{other}'")),
        }
    }
}

/// The in-process oracle: plain row-major GEMM.
#[derive(Debug, Default)]
pub struct RustOracleBackend;

impl ComputeBackend for RustOracleBackend {
    fn step_compute(
        &mut self,
        layer: &ConvLayer,
        patches: &[f32],
        kernel_matrix: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>, String> {
        let d = layer.im2col_width();
        let n = layer.n_kernels;
        if patches.len() != rows * d {
            return Err(format!(
                "patch matrix size {} != rows {rows} × D {d}",
                patches.len()
            ));
        }
        if kernel_matrix.len() != d * n {
            return Err(format!(
                "kernel matrix size {} != D {d} × N {n}",
                kernel_matrix.len()
            ));
        }
        Ok(crate::conv::reference::gemm(patches, kernel_matrix, rows, d, n))
    }

    fn name(&self) -> &str {
        "rust-oracle"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::reference;

    #[test]
    fn oracle_matches_reference_conv() {
        let l = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
        let input = reference::synth_tensor(l.input_dims().len(), 1);
        let kernels = reference::synth_tensor(l.kernel_elements(), 2);
        let group: Vec<u32> = vec![0, 4, 8];
        let pm = reference::im2col_group(&l, &input, &group);
        let km = reference::kernel_matrix(&l, &kernels);
        let mut b = RustOracleBackend;
        let got = b.step_compute(&l, &pm, &km, group.len()).unwrap();
        let want = reference::step_compute(&l, &input, &kernels, &group);
        assert_eq!(got, want);
    }

    #[test]
    fn oracle_rejects_bad_shapes() {
        let l = ConvLayer::new(1, 4, 4, 2, 2, 1, 1, 1).unwrap();
        let mut b = RustOracleBackend;
        assert!(b.step_compute(&l, &[0.0; 3], &[0.0; 4], 1).is_err());
        assert!(b.step_compute(&l, &[0.0; 4], &[0.0; 3], 1).is_err());
    }

    #[test]
    fn backend_name_roundtrip() {
        for b in [FunctionalBackend::RustOracle, FunctionalBackend::Pjrt] {
            assert_eq!(FunctionalBackend::from_str(b.as_str()), Ok(b));
        }
        assert!(FunctionalBackend::from_str("bogus").is_err());
    }
}

//! Multi-layer offload schedules: whole-CNN pipelines.
//!
//! §1.3 positions the paper's intra-layer strategies as the missing level
//! below Daini et al.'s layer-at-a-time scheduling; this module composes the
//! two: a [`Network`] is a sequence of convolution layers (with optional
//! 2×2-mean pooling between them, enough for LeNet-style topologies); each
//! layer gets its own strategy, and the pipeline report aggregates δ,
//! traffic and peak memory — with a functional mode that threads real
//! activations through every layer's stepwise offload.

use crate::conv::ConvLayer;
use crate::platform::{Accelerator, FaultModel, Platform};
use crate::sim::{ComputeBackend, SimError, Simulator};
use crate::strategy::GroupedStrategy;

/// One pipeline stage.
#[derive(Debug, Clone)]
pub struct Stage {
    /// Stage name (reports).
    pub name: String,
    /// The stage's convolution layer.
    pub layer: ConvLayer,
    /// The accelerator executing this stage.
    pub accelerator: Accelerator,
    /// The offload strategy this stage runs.
    pub strategy: GroupedStrategy,
    /// Apply 2×2 stride-2 mean pooling to this stage's output before the
    /// next stage (LeNet's subsampling).
    pub pool_after: bool,
    /// Zero-pad the (pooled) output by this many pixels per spatial side
    /// before the next stage — Remark-2 pre-padding for same-padded
    /// successors (ResNet-8's 3×3 blocks).
    pub pad_after: usize,
}

/// A feed-forward convolutional network to offload stage by stage.
#[derive(Debug, Clone, Default)]
pub struct Network {
    /// Pipeline stages in execution order.
    pub stages: Vec<Stage>,
}

/// Per-stage + aggregate results.
#[derive(Debug, Clone)]
pub struct NetworkReport {
    /// One report per pipeline stage, in execution order.
    pub per_stage: Vec<StageReport>,
    /// Sum of the per-stage durations (stage makespans under a
    /// double-buffered accelerator; stages themselves run back to back —
    /// kernels change between layers, so cross-stage overlap is not
    /// modelled).
    pub total_duration: u64,
    /// Sum of the per-stage Definition-3 sequential durations.
    pub total_sequential_duration: u64,
    /// Largest on-chip occupancy over all stages (elements).
    pub peak_occupancy: u64,
    /// DMA retries injected across all stages (0 without a fault model).
    pub fault_retries: u64,
    /// `MemoryShrink` events across all stages (0 without a fault model).
    pub mem_shrink_events: u64,
    /// Sum of the per-stage analytic k-fault WCET bounds — present only for
    /// fault-injected runs; dominates `total_duration` whenever present.
    pub wcet_bound: Option<u64>,
    /// Sum of the per-stage element-domain communication floors
    /// ([`StageReport::comm_lower_bound`]).
    pub total_comm_lower_bound: u64,
    /// Largest per-stage element-domain optimality gap.
    pub worst_optimality_gap: f64,
    /// Final activation tensor (functional mode).
    pub output: Option<Vec<f32>>,
    /// Worst per-stage functional error vs. the reference chain.
    pub max_abs_error: Option<f32>,
}

/// Aggregates of one simulated pipeline stage.
#[derive(Debug, Clone)]
pub struct StageReport {
    /// Stage name (from the [`Stage`]).
    pub name: String,
    /// Stage duration under the stage accelerator's overlap mode.
    pub duration: u64,
    /// The Definition-3 sequential duration of the same stage (equals
    /// `duration` for sequential accelerators).
    pub sequential_duration: u64,
    /// Elements loaded from DRAM across all steps.
    pub loaded_elements: u64,
    /// Peak on-chip occupancy of the stage (elements).
    pub peak_occupancy: u64,
    /// Steps executed (compute steps + terminal flush).
    pub n_steps: u64,
    /// DMA retries injected into this stage (0 without a fault model).
    pub fault_retries: u64,
    /// `MemoryShrink` events that fired in this stage.
    pub mem_shrink_events: u64,
    /// Per-stage analytic k-fault WCET bound at the trace's own retry count
    /// (fault-injected runs only; always ≥ `duration`).
    pub wcet_bound: Option<u64>,
    /// Element-domain communication floor on `loaded_elements`
    /// ([`crate::planner::certify::comm_lower_bound`]'s
    /// `load_element_floor`).
    pub comm_lower_bound: u64,
    /// `(loaded_elements − comm_lower_bound) / comm_lower_bound` (0.0 when
    /// the floor is zero).
    pub optimality_gap: f64,
}

/// Input dimensions the stage *after* `layer` sees, given the plumbing
/// flags: conv output, optionally 2×2-pooled, then re-padded. The single
/// source of truth for stage chaining (used by [`Network::push`] validation
/// and the preset chain tests).
pub fn next_stage_dims(
    layer: &ConvLayer,
    pool_after: bool,
    pad_after: usize,
) -> crate::tensor::Dims3 {
    let mut dims = layer.output_dims();
    if pool_after {
        dims.h /= 2;
        dims.w /= 2;
    }
    dims.h += 2 * pad_after;
    dims.w += 2 * pad_after;
    dims
}

impl Network {
    /// Append a stage, validating dimension chaining against the last.
    pub fn push(&mut self, stage: Stage) -> Result<(), String> {
        if let Some(prev) = self.stages.last() {
            let dims = next_stage_dims(&prev.layer, prev.pool_after, prev.pad_after);
            let next = &stage.layer;
            if next.c_in != dims.c || next.h_in != dims.h || next.w_in != dims.w {
                return Err(format!(
                    "stage '{}' expects {}x{}x{} input but previous stage produces {}",
                    stage.name, next.c_in, next.h_in, next.w_in, dims
                ));
            }
        }
        self.stages.push(stage);
        Ok(())
    }

    /// Logical pipeline simulation (fault-free).
    pub fn run(&self) -> Result<NetworkReport, SimError> {
        self.run_with_faults(None)
    }

    /// Logical pipeline simulation under an optional [`FaultModel`].
    ///
    /// Stage `i` draws from `model.for_stage(i)` — the same axes with the
    /// stage index mixed into the seed — so stages no longer replay one
    /// shared stream (step 0 of every stage used to draw identical faults).
    /// Stage 0 is the identity mix, keeping single-stage traces and their
    /// pinned baselines stable. Without a model — or with an inactive one —
    /// this is bit-identical to [`Network::run`].
    pub fn run_with_faults(
        &self,
        faults: Option<&FaultModel>,
    ) -> Result<NetworkReport, SimError> {
        let mut report = NetworkReport {
            per_stage: Vec::new(),
            total_duration: 0,
            total_sequential_duration: 0,
            peak_occupancy: 0,
            fault_retries: 0,
            mem_shrink_events: 0,
            wcet_bound: None,
            total_comm_lower_bound: 0,
            worst_optimality_gap: 0.0,
            output: None,
            max_abs_error: None,
        };
        for (i, stage) in self.stages.iter().enumerate() {
            let mut sim =
                Simulator::new(stage.layer, Platform::new(stage.accelerator));
            if let Some(m) = faults {
                sim = sim.with_faults(m.for_stage(i));
            }
            let r = sim.run(&stage.strategy)?;
            report.total_duration += r.duration;
            report.total_sequential_duration += r.sequential_duration;
            report.peak_occupancy = report.peak_occupancy.max(r.peak_occupancy);
            report.fault_retries += r.fault_retries;
            report.mem_shrink_events += r.mem_shrink_events;
            if let Some(w) = r.wcet_bound {
                *report.wcet_bound.get_or_insert(0) += w;
            }
            report.total_comm_lower_bound += r.comm_lower_bound;
            report.worst_optimality_gap =
                report.worst_optimality_gap.max(r.optimality_gap);
            report.per_stage.push(StageReport {
                name: stage.name.clone(),
                duration: r.duration,
                sequential_duration: r.sequential_duration,
                loaded_elements: r.total_loaded(),
                peak_occupancy: r.peak_occupancy,
                n_steps: r.totals.n_steps,
                fault_retries: r.fault_retries,
                mem_shrink_events: r.mem_shrink_events,
                wcet_bound: r.wcet_bound,
                comm_lower_bound: r.comm_lower_bound,
                optimality_gap: r.optimality_gap,
            });
        }
        Ok(report)
    }

    /// Functional pipeline: stage outputs (after optional pooling) feed the
    /// next stage; every stage's stepwise result is checked against its own
    /// reference convolution.
    pub fn run_functional(
        &self,
        input: &[f32],
        per_stage_kernels: &[Vec<f32>],
        backend: &mut dyn ComputeBackend,
    ) -> Result<NetworkReport, SimError> {
        if per_stage_kernels.len() != self.stages.len() {
            return Err(SimError::BadTensors(format!(
                "{} kernel tensors for {} stages",
                per_stage_kernels.len(),
                self.stages.len()
            )));
        }
        let mut report = NetworkReport {
            per_stage: Vec::new(),
            total_duration: 0,
            total_sequential_duration: 0,
            peak_occupancy: 0,
            fault_retries: 0,
            mem_shrink_events: 0,
            wcet_bound: None,
            total_comm_lower_bound: 0,
            worst_optimality_gap: 0.0,
            output: None,
            max_abs_error: Some(0.0),
        };
        let mut activation = input.to_vec();
        for (stage, kernels) in self.stages.iter().zip(per_stage_kernels) {
            let sim =
                Simulator::new(stage.layer, Platform::new(stage.accelerator));
            let r = sim.run_functional(&stage.strategy, &activation, kernels, backend)?;
            let err = r.max_abs_error.unwrap_or(f32::INFINITY);
            report.max_abs_error =
                Some(report.max_abs_error.unwrap().max(err));
            report.total_duration += r.duration;
            report.total_sequential_duration += r.sequential_duration;
            report.peak_occupancy = report.peak_occupancy.max(r.peak_occupancy);
            report.total_comm_lower_bound += r.comm_lower_bound;
            report.worst_optimality_gap =
                report.worst_optimality_gap.max(r.optimality_gap);
            report.per_stage.push(StageReport {
                name: stage.name.clone(),
                duration: r.duration,
                sequential_duration: r.sequential_duration,
                loaded_elements: r.total_loaded(),
                peak_occupancy: r.peak_occupancy,
                n_steps: r.totals.n_steps,
                fault_retries: 0,
                mem_shrink_events: 0,
                wcet_bound: None,
                comm_lower_bound: r.comm_lower_bound,
                optimality_gap: r.optimality_gap,
            });
            activation = r.output.expect("functional mode fills output");
            let mut dims = stage.layer.output_dims();
            if stage.pool_after {
                activation = mean_pool_2x2(&dims, &activation);
                dims.h /= 2;
                dims.w /= 2;
            }
            if stage.pad_after > 0 {
                activation = zero_pad(&dims, &activation, stage.pad_after);
            }
        }
        report.output = Some(activation);
        Ok(report)
    }
}

/// 2×2 stride-2 mean pooling over `[C, H, W]` (truncating odd edges).
pub fn mean_pool_2x2(dims: &crate::tensor::Dims3, x: &[f32]) -> Vec<f32> {
    let (c, h, w) = (dims.c, dims.h, dims.w);
    let (ho, wo) = (h / 2, w / 2);
    let mut out = vec![0f32; c * ho * wo];
    for ci in 0..c {
        for i in 0..ho {
            for j in 0..wo {
                let base = ci * h * w + 2 * i * w + 2 * j;
                out[(ci * ho + i) * wo + j] =
                    (x[base] + x[base + 1] + x[base + w] + x[base + w + 1]) / 4.0;
            }
        }
    }
    out
}

/// Zero-pad a `[C, H, W]` tensor by `pad` pixels on each spatial side
/// (Remark-2 pre-padding applied between stages).
pub fn zero_pad(dims: &crate::tensor::Dims3, x: &[f32], pad: usize) -> Vec<f32> {
    if pad == 0 {
        return x.to_vec();
    }
    let (c, h, w) = (dims.c, dims.h, dims.w);
    let (hp, wp) = (h + 2 * pad, w + 2 * pad);
    let mut out = vec![0f32; c * hp * wp];
    for ci in 0..c {
        for i in 0..h {
            let src = (ci * h + i) * w;
            let dst = (ci * hp + i + pad) * wp + pad;
            out[dst..dst + w].copy_from_slice(&x[src..src + w]);
        }
    }
    out
}

/// Build the LeNet-5 convolutional trunk (conv1 → pool → conv2) with the
/// given per-stage strategies.
pub fn lenet5_trunk(
    strategy_for: impl Fn(&ConvLayer, usize) -> GroupedStrategy,
    group: usize,
) -> Network {
    let conv1 = ConvLayer::new(1, 32, 32, 5, 5, 6, 1, 1).unwrap();
    let conv2 = ConvLayer::new(6, 14, 14, 5, 5, 16, 1, 1).unwrap();
    let mut net = Network::default();
    net.push(Stage {
        name: "conv1".into(),
        layer: conv1,
        accelerator: Accelerator::for_group_size(&conv1, group),
        strategy: strategy_for(&conv1, group),
        pool_after: true,
        pad_after: 0,
    })
    .unwrap();
    net.push(Stage {
        name: "conv2".into(),
        layer: conv2,
        accelerator: Accelerator::for_group_size(&conv2, group),
        strategy: strategy_for(&conv2, group),
        pool_after: false,
        pad_after: 0,
    })
    .unwrap();
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::reference;
    use crate::sim::RustOracleBackend;
    use crate::strategy;

    #[test]
    fn dimension_mismatch_rejected() {
        let conv1 = ConvLayer::new(1, 8, 8, 3, 3, 2, 1, 1).unwrap();
        let bad = ConvLayer::new(3, 6, 6, 3, 3, 1, 1, 1).unwrap(); // wrong C_in
        let mut net = Network::default();
        net.push(Stage {
            name: "a".into(),
            layer: conv1,
            accelerator: Accelerator::for_group_size(&conv1, 2),
            strategy: strategy::zigzag(&conv1, 2),
            pool_after: false,
            pad_after: 0,
        })
        .unwrap();
        assert!(net
            .push(Stage {
                name: "b".into(),
                layer: bad,
                accelerator: Accelerator::for_group_size(&bad, 2),
                strategy: strategy::zigzag(&bad, 2),
                pool_after: false,
                pad_after: 0,
            })
            .is_err());
    }

    #[test]
    fn mean_pool_2x2_values() {
        let dims = crate::tensor::Dims3::new(1, 4, 4);
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let out = mean_pool_2x2(&dims, &x);
        // windows: [0,1,4,5]→2.5 [2,3,6,7]→4.5 [8,9,12,13]→10.5 [10,11,14,15]→12.5
        assert_eq!(out, vec![2.5, 4.5, 10.5, 12.5]);
    }

    #[test]
    fn two_stage_functional_pipeline() {
        // 1x8x8 → conv(2 kernels 3x3) → 2x6x6 → pool → 2x3x3 → conv(1 kernel 3x3)
        let conv1 = ConvLayer::new(1, 8, 8, 3, 3, 2, 1, 1).unwrap();
        let conv2 = ConvLayer::new(2, 3, 3, 3, 3, 1, 1, 1).unwrap();
        let mut net = Network::default();
        net.push(Stage {
            name: "c1".into(),
            layer: conv1,
            accelerator: Accelerator::for_group_size(&conv1, 2),
            strategy: strategy::zigzag(&conv1, 2),
            pool_after: true,
            pad_after: 0,
        })
        .unwrap();
        net.push(Stage {
            name: "c2".into(),
            layer: conv2,
            accelerator: Accelerator::for_group_size(&conv2, 1),
            strategy: strategy::s1_baseline(&conv2),
            pool_after: false,
            pad_after: 0,
        })
        .unwrap();

        let input = reference::synth_tensor(64, 1);
        let k1 = reference::synth_tensor(conv1.kernel_elements(), 2);
        let k2 = reference::synth_tensor(conv2.kernel_elements(), 3);
        let mut backend = RustOracleBackend;
        let r = net
            .run_functional(&input, &[k1.clone(), k2.clone()], &mut backend)
            .unwrap();
        assert!(r.max_abs_error.unwrap() < 1e-4);
        assert_eq!(r.per_stage.len(), 2);
        assert_eq!(r.output.as_ref().unwrap().len(), 1); // 1x1x1

        // cross-check the final activation against a direct reference chain
        let a1 = reference::conv2d(&conv1, &input, &k1);
        let pooled = mean_pool_2x2(&conv1.output_dims(), &a1);
        let a2 = reference::conv2d(&conv2, &pooled, &k2);
        let got = r.output.unwrap();
        assert!((got[0] - a2[0]).abs() < 1e-4);
    }

    #[test]
    fn zero_pad_values() {
        let dims = crate::tensor::Dims3::new(2, 2, 2);
        let x: Vec<f32> = (1..=8).map(|v| v as f32).collect();
        let out = zero_pad(&dims, &x, 1);
        assert_eq!(out.len(), 2 * 4 * 4);
        // channel 0: values 1..4 centred in a 4x4 zero frame
        assert_eq!(out[5], 1.0);
        assert_eq!(out[6], 2.0);
        assert_eq!(out[9], 3.0);
        assert_eq!(out[10], 4.0);
        // channel 1 offset by 16
        assert_eq!(out[16 + 5], 5.0);
        assert_eq!(out[16 + 10], 8.0);
        // frame stays zero
        assert_eq!(out[0], 0.0);
        assert_eq!(out[15], 0.0);
        // pad = 0 is the identity
        assert_eq!(zero_pad(&dims, &x, 0), x);
    }

    /// A ResNet-style same-padded chain: conv output is re-padded so the next
    /// stage sees the same spatial size; the functional result must equal the
    /// direct reference chain with explicit padding.
    #[test]
    fn padded_functional_chain() {
        // 1x6x6 → conv 3x3 → 1x4x4 → pad 1 → 1x6x6 → conv 3x3 → 1x4x4
        let conv = ConvLayer::new(1, 6, 6, 3, 3, 1, 1, 1).unwrap();
        let mut net = Network::default();
        net.push(Stage {
            name: "c1".into(),
            layer: conv,
            accelerator: Accelerator::for_group_size(&conv, 2),
            strategy: strategy::zigzag(&conv, 2),
            pool_after: false,
            pad_after: 1,
        })
        .unwrap();
        net.push(Stage {
            name: "c2".into(),
            layer: conv,
            accelerator: Accelerator::for_group_size(&conv, 2),
            strategy: strategy::zigzag(&conv, 2),
            pool_after: false,
            pad_after: 0,
        })
        .unwrap();

        let input = reference::synth_tensor(36, 5);
        let k1 = reference::synth_tensor(conv.kernel_elements(), 6);
        let k2 = reference::synth_tensor(conv.kernel_elements(), 7);
        let mut backend = RustOracleBackend;
        let r = net
            .run_functional(&input, &[k1.clone(), k2.clone()], &mut backend)
            .unwrap();
        assert!(r.max_abs_error.unwrap() < 1e-4);

        let a1 = reference::conv2d(&conv, &input, &k1);
        let padded = zero_pad(&conv.output_dims(), &a1, 1);
        let a2 = reference::conv2d(&conv, &padded, &k2);
        let got = r.output.unwrap();
        assert_eq!(got.len(), a2.len());
        for (g, w) in got.iter().zip(&a2) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    /// Padding mismatches are caught at push time.
    #[test]
    fn pad_mismatch_rejected() {
        let conv = ConvLayer::new(1, 6, 6, 3, 3, 1, 1, 1).unwrap();
        let mut net = Network::default();
        net.push(Stage {
            name: "c1".into(),
            layer: conv,
            accelerator: Accelerator::for_group_size(&conv, 2),
            strategy: strategy::zigzag(&conv, 2),
            pool_after: false,
            pad_after: 0, // produces 4x4, next expects 6x6
        })
        .unwrap();
        assert!(net
            .push(Stage {
                name: "c2".into(),
                layer: conv,
                accelerator: Accelerator::for_group_size(&conv, 2),
                strategy: strategy::zigzag(&conv, 2),
                pool_after: false,
                pad_after: 0,
            })
            .is_err());
    }

    /// Double-buffered stage accelerators lower (never raise) the pipeline
    /// duration, and the sequential totals stay equal either way.
    #[test]
    fn double_buffered_stages_reduce_the_pipeline_duration() {
        use crate::platform::OverlapMode;
        let run_with = |overlap: OverlapMode| {
            let base = lenet5_trunk(|l, g| strategy::zigzag(l, g), 4);
            let mut net = Network::default();
            for s in base.stages {
                net.push(Stage { accelerator: s.accelerator.with_overlap(overlap), ..s })
                    .unwrap();
            }
            net.run().unwrap()
        };
        let seq = run_with(OverlapMode::Sequential);
        let db = run_with(OverlapMode::DoubleBuffered);
        assert_eq!(seq.total_duration, seq.total_sequential_duration);
        assert_eq!(db.total_sequential_duration, seq.total_duration);
        assert!(db.total_duration <= seq.total_duration);
        assert_eq!(
            db.total_duration,
            db.per_stage.iter().map(|s| s.duration).sum::<u64>()
        );
        for s in &db.per_stage {
            assert!(s.duration <= s.sequential_duration, "{}", s.name);
        }
    }

    /// Fault-injected pipelines: zero faults are the identity, an active
    /// model is deterministic, inflates totals monotonically, and the summed
    /// per-stage WCET bound dominates the whole trace.
    #[test]
    fn fault_injected_pipeline_is_bounded_and_deterministic() {
        let net = lenet5_trunk(|l, g| strategy::zigzag(l, g), 4);
        let clean = net.run().unwrap();
        let zero = net.run_with_faults(Some(&FaultModel::none())).unwrap();
        assert_eq!(zero.total_duration, clean.total_duration);
        assert_eq!(zero.wcet_bound, None);

        let m = FaultModel {
            seed: 11,
            dma_fail_rate: 0.2,
            max_retries: 2,
            retry_penalty: 4,
            dma_jitter: 2,
            t_acc_jitter: 1,
            shrink_rate: 0.05,
            shrink_elements: 8,
        };
        let a = net.run_with_faults(Some(&m)).unwrap();
        let b = net.run_with_faults(Some(&m)).unwrap();
        assert_eq!(a.total_duration, b.total_duration);
        assert_eq!(a.fault_retries, b.fault_retries);
        assert!(a.total_duration >= clean.total_duration);
        assert!(a.fault_retries > 0, "rate 0.2 across the trunk must retry");
        let wcet = a.wcet_bound.expect("bound present under faults");
        assert!(wcet >= a.total_duration);
        for s in &a.per_stage {
            assert!(s.wcet_bound.unwrap() >= s.duration, "{}", s.name);
        }
        assert_eq!(
            a.fault_retries,
            a.per_stage.iter().map(|s| s.fault_retries).sum::<u64>()
        );
    }

    /// Stage `i` of a faulted pipeline must be reproducible standalone under
    /// `model.for_stage(i)` — the decorrelation is a seed transform, not a
    /// hidden pipeline state.
    #[test]
    fn faulted_stages_replay_standalone_under_the_mixed_seed() {
        let net = lenet5_trunk(|l, g| strategy::zigzag(l, g), 4);
        let m = FaultModel {
            seed: 13,
            dma_fail_rate: 0.35,
            max_retries: 3,
            retry_penalty: 9,
            dma_jitter: 4,
            t_acc_jitter: 3,
            shrink_rate: 0.15,
            shrink_elements: 32,
        };
        let r = net.run_with_faults(Some(&m)).unwrap();
        for (i, stage) in net.stages.iter().enumerate() {
            let solo = Simulator::new(stage.layer, Platform::new(stage.accelerator))
                .with_faults(m.for_stage(i))
                .run(&stage.strategy)
                .unwrap();
            assert_eq!(solo.duration, r.per_stage[i].duration, "stage {i}");
            assert_eq!(solo.fault_retries, r.per_stage[i].fault_retries, "stage {i}");
            assert_eq!(
                solo.mem_shrink_events, r.per_stage[i].mem_shrink_events,
                "stage {i}"
            );
        }
    }

    #[test]
    fn lenet_trunk_logical() {
        let net = lenet5_trunk(|l, g| strategy::zigzag(l, g), 4);
        let r = net.run().unwrap();
        assert_eq!(r.per_stage.len(), 2);
        assert_eq!(
            r.total_duration,
            r.per_stage.iter().map(|s| s.duration).sum::<u64>()
        );
        assert!(r.per_stage[0].n_steps > r.per_stage[1].n_steps);
    }

    /// Every simulated stage respects its element-domain communication
    /// floor, and the report aggregates are the sum / max of the stages.
    #[test]
    fn stage_floors_bound_the_loads() {
        let net = lenet5_trunk(|l, g| strategy::zigzag(l, g), 4);
        let r = net.run().unwrap();
        let mut total = 0u64;
        let mut worst = 0.0f64;
        for s in &r.per_stage {
            assert!(s.comm_lower_bound > 0, "{}", s.name);
            assert!(s.comm_lower_bound <= s.loaded_elements, "{}", s.name);
            total += s.comm_lower_bound;
            worst = worst.max(s.optimality_gap);
        }
        assert_eq!(r.total_comm_lower_bound, total);
        assert_eq!(r.worst_optimality_gap, worst);
    }

    #[test]
    fn kernel_count_mismatch_rejected() {
        let net = lenet5_trunk(|l, g| strategy::zigzag(l, g), 4);
        let input = reference::synth_tensor(32 * 32, 1);
        let mut backend = RustOracleBackend;
        assert!(matches!(
            net.run_functional(&input, &[vec![]], &mut backend),
            Err(SimError::BadTensors(_))
        ));
    }
}

//! The simulator (§6): step-by-step execution of a strategy on the platform
//! model, with metrics, trace recording and functional simulation.
//!
//! The engine follows the paper's orchestration loop exactly: at each step it
//! 1) reads the step from the strategy, 2) frees the unnecessary elements,
//! 3) writes results to DRAM, 4) loads elements from DRAM, 5) triggers the
//! accelerator compute, 6) loops. The *logical* simulation tracks sets and
//! costs only; the *functional* simulation additionally moves real `f32`
//! values through the modelled memories and checks the stepwise result
//! against the whole-layer reference convolution.

mod backend;
mod engine;
pub mod network;
mod report;

pub use backend::{ComputeBackend, FunctionalBackend, RustOracleBackend};
pub use engine::{SimError, Simulator};
pub use network::{Network, NetworkReport, Stage};
pub use report::{summary_line, SimReport, StepRecord};

//! Integration tests over the PJRT runtime path (the three-layer contract):
//! Rust coordinator → AOT HLO artifacts → XLA CPU executables.
//!
//! All tests self-skip (with a note) when `make artifacts` has not run, so
//! `cargo test` works in a fresh checkout; CI runs `make test` which builds
//! artifacts first.

use convoffload::config::layer_preset;
use convoffload::conv::{reference, ConvLayer};
use convoffload::platform::{Accelerator, Platform};
use convoffload::runtime::{artifacts_available, PjrtBackend, Runtime};
use convoffload::sim::{ComputeBackend, RustOracleBackend, Simulator};
use convoffload::strategy;

fn skip() -> bool {
    if !artifacts_available() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return true;
    }
    false
}

#[test]
fn manifest_covers_the_preset_layers() {
    if skip() {
        return;
    }
    let rt = Runtime::from_default_dir().unwrap();
    // step artifacts for the preset layers the examples use
    for (d, n) in [(9usize, 1usize), (18, 2), (25, 6), (150, 16)] {
        assert!(
            rt.manifest.find_step(d, n, 8).is_some(),
            "missing step artifact d={d} n={n}"
        );
    }
    // whole-layer artifacts for the e2e example
    assert!(rt.manifest.find_layer(1, 32, 32, 6, 5).is_some());
    assert!(rt.manifest.find_layer(6, 14, 14, 16, 5).is_some());
}

#[test]
fn pjrt_matches_oracle_on_every_artifact_family() {
    if skip() {
        return;
    }
    let mut pjrt = PjrtBackend::from_default_dir().unwrap();
    let mut oracle = RustOracleBackend;
    // one layer per artifact family
    let layers = [
        ConvLayer::new(1, 8, 8, 3, 3, 1, 1, 1).unwrap(),   // d=9 n=1
        ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap(),   // d=18 n=2
        ConvLayer::new(1, 32, 32, 5, 5, 6, 1, 1).unwrap(), // d=25 n=6
        ConvLayer::new(6, 14, 14, 5, 5, 16, 1, 1).unwrap(),// d=150 n=16
    ];
    for layer in layers {
        let input = reference::synth_tensor(layer.input_dims().len(), 51);
        let kernels = reference::synth_tensor(layer.kernel_elements(), 52);
        let km = reference::kernel_matrix(&layer, &kernels);
        let group: Vec<u32> = (0..4.min(layer.n_patches() as u32)).collect();
        let pm = reference::im2col_group(&layer, &input, &group);
        let got = pjrt.step_compute(&layer, &pm, &km, group.len()).unwrap();
        let want = oracle.step_compute(&layer, &pm, &km, group.len()).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "layer {layer}: {a} vs {b}");
        }
    }
}

#[test]
fn full_functional_pipeline_on_lenet_conv2() {
    if skip() {
        return;
    }
    let layer = layer_preset("lenet5-conv2").unwrap().layer;
    let acc = Accelerator::for_group_size(&layer, 4);
    let sim = Simulator::new(layer, Platform::new(acc));
    let input = reference::synth_tensor(layer.input_dims().len(), 61);
    let kernels = reference::synth_tensor(layer.kernel_elements(), 62);
    let mut backend = PjrtBackend::from_default_dir().unwrap();
    let report = sim
        .run_functional(&strategy::zigzag(&layer, 4), &input, &kernels, &mut backend)
        .unwrap();
    assert_eq!(report.functional_ok(1e-3), Some(true));
    // 100 patches in groups of 4 → 25 compute steps
    assert_eq!(report.n_compute_steps(), 25);
}

#[test]
fn pjrt_and_oracle_produce_identical_strategy_metrics() {
    if skip() {
        return;
    }
    // metrics (δ, loads, peak) are backend-independent; outputs agree too
    let layer = layer_preset("example1").unwrap().layer;
    let acc = Accelerator::for_group_size(&layer, 2);
    let sim = Simulator::new(layer, Platform::new(acc));
    let input = reference::synth_tensor(layer.input_dims().len(), 71);
    let kernels = reference::synth_tensor(layer.kernel_elements(), 72);
    let s = strategy::diagonal(&layer, 2);

    let mut pjrt = PjrtBackend::from_default_dir().unwrap();
    let a = sim.run_functional(&s, &input, &kernels, &mut pjrt).unwrap();
    let mut oracle = RustOracleBackend;
    let b = sim.run_functional(&s, &input, &kernels, &mut oracle).unwrap();

    assert_eq!(a.duration, b.duration);
    assert_eq!(a.total_loaded(), b.total_loaded());
    assert_eq!(a.peak_occupancy, b.peak_occupancy);
    let (ao, bo) = (a.output.unwrap(), b.output.unwrap());
    for (x, y) in ao.iter().zip(&bo) {
        assert!((x - y).abs() < 1e-4);
    }
}

#[test]
fn whole_layer_artifact_agrees_with_rust_reference() {
    if skip() {
        return;
    }
    let mut rt = Runtime::from_default_dir().unwrap();
    let v = rt.manifest.find_layer(1, 32, 32, 6, 5).unwrap().clone();
    let layer =
        ConvLayer::new(v.c_in, v.h_in, v.w_in, v.h_k, v.w_k, v.n, v.s_h, v.s_w).unwrap();
    let input = reference::synth_tensor(layer.input_dims().len(), 81);
    let kernels = reference::synth_tensor(layer.kernel_elements(), 82);
    let out = rt
        .execute_f32(
            &v.file,
            &[
                (&input, &[v.c_in, v.h_in, v.w_in]),
                (&kernels, &[v.n, v.c_in, v.h_k, v.w_k]),
            ],
        )
        .unwrap();
    let want = reference::conv2d(&layer, &input, &kernels);
    let max_err = out
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "max err {max_err}");
}

#[test]
fn compile_cache_reuses_executables() {
    if skip() {
        return;
    }
    let mut rt = Runtime::from_default_dir().unwrap();
    let v = rt.manifest.find_step(9, 1, 8).unwrap().clone();
    let patches = vec![0.5f32; v.g_max * 9];
    let kernels = vec![1f32; 9];
    for _ in 0..3 {
        rt.execute_f32(&v.file, &[(&patches, &[v.g_max, 9]), (&kernels, &[9, 1])])
            .unwrap();
    }
    assert_eq!(rt.cached(), 1);
}

#[test]
fn multipass_strategy_through_pjrt() {
    if skip() {
        return;
    }
    // LeNet-5 conv2 split into 8-kernel passes: each pass is a d=150, n=8
    // sub-layer… no such artifact exists, so use the 16-kernel layer split
    // into 16×1? The d=150/n=16 artifact only covers full Λ — use the
    // example1 layer (d=18, n=2) split into two 1-kernel passes; the
    // backend falls back to an error if no (d, n) variant exists, so this
    // also pins the manifest coverage expectations.
    let layer = ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap();
    let sub = {
        let mut s = layer;
        s.n_kernels = 1;
        s
    };
    // d=18, n=1 has no artifact → expect a clean error, not a wrong result
    let mp = convoffload::strategy::MultiPassStrategy::new(
        &layer,
        1,
        convoffload::strategy::zigzag(&sub, 2),
    )
    .unwrap();
    let acc = Accelerator::for_group_size(&sub, 2);
    let input = reference::synth_tensor(layer.input_dims().len(), 95);
    let kernels = reference::synth_tensor(layer.kernel_elements(), 96);
    let mut backend = PjrtBackend::from_default_dir().unwrap();
    match mp.run_functional(&layer, &acc, &input, &kernels, &mut backend) {
        Err(convoffload::sim::SimError::Backend(msg)) => {
            assert!(msg.contains("no step artifact"), "{msg}");
        }
        Ok(r) => {
            // if a d=18/n=1 artifact is added later this must be correct
            assert!(r.max_abs_error.unwrap() < 1e-3);
        }
        Err(other) => panic!("unexpected error {other:?}"),
    }
    // single-pass (= S1) through PJRT must work with the existing artifact
    let mp1 = convoffload::strategy::MultiPassStrategy::new(
        &layer,
        2,
        convoffload::strategy::zigzag(&layer, 2),
    )
    .unwrap();
    let acc = Accelerator::for_group_size(&layer, 2);
    let r = mp1
        .run_functional(&layer, &acc, &input, &kernels, &mut backend)
        .unwrap();
    assert!(r.max_abs_error.unwrap() < 1e-3);
}

#[test]
fn lenet_trunk_functional_through_pjrt() {
    if skip() {
        return;
    }
    // Full two-stage LeNet trunk with pooling, every step's compute on PJRT.
    let net = convoffload::sim::network::lenet5_trunk(
        |l, g| convoffload::strategy::zigzag(l, g),
        4,
    );
    let input = reference::synth_tensor(32 * 32, 7);
    let k1 = reference::synth_tensor(6 * 1 * 5 * 5, 8);
    let k2 = reference::synth_tensor(16 * 6 * 5 * 5, 9);
    let mut backend = PjrtBackend::from_default_dir().unwrap();
    let r = net
        .run_functional(&input, &[k1, k2], &mut backend)
        .unwrap();
    assert!(r.max_abs_error.unwrap() < 1e-3, "err {:?}", r.max_abs_error);
    assert_eq!(r.per_stage.len(), 2);
    // final activation: 16×10×10
    assert_eq!(r.output.unwrap().len(), 1600);
}

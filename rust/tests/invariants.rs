//! Property-based tests over the coordinator invariants.
//!
//! Uses the in-tree property-test helper (`util::proptest`): random layers,
//! accelerators and *random valid groupings* are generated; the invariants
//! of the formalism must hold for every case:
//!
//! 1. the on-chip memory never exceeds `size_MEM`;
//! 2. every patch is computed exactly once;
//! 3. the memory is empty after the final step, all outputs written;
//! 4. the functional simulation reproduces the reference convolution;
//! 5. simulator duration == fast-objective duration (+ kernel-load term);
//! 6. strategy CSV/JSON round-trips preserve semantics;
//! 7. the §3.10 multi-resource timeline collapses bit-exactly to the scalar
//!    §3.7 recurrence at k = m = 1, is monotone non-increasing in both k
//!    and m, and stays within the resource-floor/sequential envelope.

use convoffload::config::fuzz;
use convoffload::conv::ConvLayer;
use convoffload::optimizer::overlap::OverlapGraph;
use convoffload::optimizer::{grouping_duration, grouping_loads};
use convoffload::platform::{Accelerator, OverlapMode, Platform};
use convoffload::sim::{RustOracleBackend, Simulator};
use convoffload::step::OverlapTimeline;
use convoffload::strategy::{
    self, strategy_from_csv, strategy_from_json, strategy_to_csv, strategy_to_json,
    GroupedStrategy,
};
use convoffload::util::proptest::{check, Config};
use convoffload::util::rng::Rng;

/// A randomly generated scenario.
#[derive(Debug, Clone)]
struct Scenario {
    layer: ConvLayer,
    group_size: usize,
    strategy: GroupedStrategy,
}

/// Random generalized layer: delegates to the fuzzer's sampler
/// (`config::fuzz::random_layer` — strides, dilation, channel groups incl.
/// depthwise) over a random small input, so the property tests
/// automatically cover every feature axis the fuzzer grows.
fn gen_layer(rng: &mut Rng) -> ConvLayer {
    let c = 1 + rng.index(4);
    let h = 4 + rng.index(12);
    let w = 4 + rng.index(12);
    fuzz::random_layer(rng, c, h, w)
}

fn gen_scenario(rng: &mut Rng) -> Scenario {
    let layer = gen_layer(rng);
    let group_size = 1 + rng.index(4);
    // random permutation of patches chunked into groups ≤ group_size
    let mut order: Vec<u32> = layer.all_patches().collect();
    rng.shuffle(&mut order);
    let mut groups = Vec::new();
    let mut idx = 0;
    while idx < order.len() {
        let take = 1 + rng.index(group_size.min(order.len() - idx));
        groups.push(order[idx..idx + take].to_vec());
        idx += take;
    }
    Scenario {
        layer,
        group_size,
        strategy: GroupedStrategy::new("random", groups),
    }
}

fn shrink_scenario(s: &Scenario, _rng: &mut Rng) -> Vec<Scenario> {
    // drop the last group + its patches… not semantically valid (patches
    // must cover X); instead shrink by merging the two smallest groups and
    // by sorting groups toward row-major (tamer orderings).
    let mut out = Vec::new();
    if s.strategy.groups.len() >= 2 {
        let mut groups = s.strategy.groups.clone();
        let tail = groups.pop().unwrap();
        let last = groups.last_mut().unwrap();
        if last.len() + tail.len() <= s.group_size {
            last.extend(tail);
            out.push(Scenario {
                layer: s.layer,
                group_size: s.group_size,
                strategy: GroupedStrategy::new("shrunk-merge", groups),
            });
        }
    }
    let mut sorted = s.strategy.groups.clone();
    sorted.sort_by_key(|g| g.iter().min().copied());
    if sorted != s.strategy.groups {
        out.push(Scenario {
            layer: s.layer,
            group_size: s.group_size,
            strategy: GroupedStrategy::new("shrunk-sort", sorted),
        });
    }
    out
}

fn accelerator_for(s: &Scenario) -> Accelerator {
    // size for the worst group of THIS strategy (groups are ≤ group_size
    // but arbitrary patches may overlap little)
    let worst_group = s
        .strategy
        .groups
        .iter()
        .map(|g| s.layer.group_pixels(g).len())
        .max()
        .unwrap_or(0);
    Accelerator {
        nbop_pe: (s.group_size * s.layer.ops_per_patch()) as u64,
        t_acc: 1,
        size_mem: (worst_group * s.layer.c_in
            + s.layer.kernel_elements()
            + s.group_size * s.layer.c_out() * 2) as u64,
        t_l: 1,
        t_w: 1,
        overlap: OverlapMode::Sequential,
        dma_channels: 1,
        compute_units: 1,
    }
}

/// §3.7 property: for every generated scenario (and a 2× memory variant
/// that lets prefetches through), the double-buffered makespan is bounded
/// above by the sequential Definition-3 duration and below by the busier
/// resource: `max(dma_busy, compute_busy) ≤ makespan ≤ δ_sequential`.
/// The fuzz networks (`config::fuzz`) are covered by the same property in
/// `overlapped_fuzz_networks_respect_the_bounds`.
#[test]
fn overlapped_makespan_bounds_invariant() {
    let cfg = Config { cases: 120, ..Default::default() };
    check(&cfg, gen_scenario, shrink_scenario, |s| {
        let base = accelerator_for(s);
        let seq = Simulator::new(s.layer, Platform::new(base))
            .run(&s.strategy)
            .map_err(|e| format!("sequential simulation failed: {e}"))?;
        for mem_factor in [1u64, 2] {
            let acc = Accelerator { size_mem: base.size_mem * mem_factor, ..base }
                .with_overlap(OverlapMode::DoubleBuffered);
            let ovl = Simulator::new(s.layer, Platform::new(acc))
                .run(&s.strategy)
                .map_err(|e| format!("overlapped simulation failed: {e}"))?;
            if ovl.sequential_duration != seq.duration {
                return Err(format!(
                    "sequential accounting diverged: {} != {}",
                    ovl.sequential_duration, seq.duration
                ));
            }
            if ovl.duration > seq.duration {
                return Err(format!(
                    "makespan {} above sequential {} (mem x{mem_factor})",
                    ovl.duration, seq.duration
                ));
            }
            let floor = ovl.dma_busy.max(ovl.compute_busy);
            if ovl.duration < floor {
                return Err(format!(
                    "makespan {} below resource floor {floor} (mem x{mem_factor})",
                    ovl.duration
                ));
            }
        }
        Ok(())
    });
}

/// The same §3.7 bounds over the seeded fuzz networks — every stage of
/// every differential seed, in both the tight and the roomy memory
/// configuration.
#[test]
fn overlapped_fuzz_networks_respect_the_bounds() {
    for seed in 1..=24u64 {
        let net = fuzz::random_network(seed);
        for stage in &net.stages {
            let seq = Simulator::new(stage.layer, Platform::new(stage.accelerator))
                .run(&stage.strategy)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            for mem_factor in [1u64, 2] {
                let acc = Accelerator {
                    size_mem: stage.accelerator.size_mem * mem_factor,
                    ..stage.accelerator
                }
                .with_overlap(OverlapMode::DoubleBuffered);
                let ovl = Simulator::new(stage.layer, Platform::new(acc))
                    .run(&stage.strategy)
                    .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
                assert!(
                    ovl.duration <= seq.duration,
                    "seed {seed} stage {}: makespan {} > sequential {}",
                    stage.name,
                    ovl.duration,
                    seq.duration
                );
                assert!(
                    ovl.duration >= ovl.dma_busy.max(ovl.compute_busy),
                    "seed {seed} stage {}: makespan below the resource floor",
                    stage.name
                );
            }
        }
    }
}

/// §3.10 collapse: at k = m = 1 the generalized list scheduler must be
/// bit-identical to the legacy scalar §3.7 recurrence. Every double-buffered
/// fuzz stage is replayed step by step through the scalar
/// [`OverlapTimeline::place`] reference and every phase instant compared;
/// under the sequential mode the duration must ignore the resource shape
/// entirely. All 24 differential seeds, both overlap modes.
#[test]
fn multi_resource_collapses_to_scalar_on_fuzz_networks() {
    for seed in 1..=24u64 {
        let net = fuzz::random_network(seed);
        for stage in &net.stages {
            let seq = Simulator::new(stage.layer, Platform::new(stage.accelerator))
                .run(&stage.strategy)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            for (k, m) in [(2, 1), (1, 2), (3, 3)] {
                let acc = stage.accelerator.with_channels(k, m);
                let r = Simulator::new(stage.layer, Platform::new(acc))
                    .run(&stage.strategy)
                    .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
                assert_eq!(
                    r.duration, seq.duration,
                    "seed {seed} stage {}: sequential duration depends on {k}x{m}",
                    stage.name
                );
            }
            let db = stage.accelerator.with_overlap(OverlapMode::DoubleBuffered);
            let ovl = Simulator::new(stage.layer, Platform::new(db))
                .run(&stage.strategy)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            let (mut dma_free, mut comp_end, mut prev_occ) = (0u64, 0u64, 0u64);
            for st in &ovl.steps {
                let can_prefetch = prev_occ + st.cost.loaded_elements <= db.size_mem;
                let t = OverlapTimeline::place(
                    dma_free,
                    comp_end,
                    st.cost.load_cycles(&db),
                    st.cost.written_elements * db.t_w,
                    st.cost.compute_cycles(&db),
                    can_prefetch,
                );
                assert_eq!(
                    st.timing,
                    Some(t),
                    "seed {seed} stage {} step {}: 1x1 placement diverged from \
                     the scalar recurrence",
                    stage.name,
                    st.index
                );
                dma_free = t.write_end;
                comp_end = t.compute_end;
                prev_occ = st.occupancy;
            }
            assert_eq!(
                ovl.duration,
                dma_free.max(comp_end),
                "seed {seed} stage {}: makespan is not the latest frontier",
                stage.name
            );
        }
    }
}

/// §3.10 monotonicity and resource floor over the fuzz networks: adding DMA
/// channels or compute units never increases the double-buffered makespan
/// (at batch 1 and batch 4), every makespan stays within
/// `[max(⌈dma_busy/k⌉, ⌈compute_busy/m⌉), δ_sequential]`, and the
/// per-resource busy vectors account for the class totals exactly.
#[test]
fn multi_resource_makespans_are_monotone_and_floored() {
    for seed in 1..=24u64 {
        let net = fuzz::random_network(seed);
        for stage in &net.stages {
            let db = stage.accelerator.with_overlap(OverlapMode::DoubleBuffered);
            for batch in [1usize, 4] {
                let mut grid = [[0u64; 3]; 3];
                for k in 1..=3usize {
                    for m in 1..=3usize {
                        let acc = db.with_channels(k, m);
                        let r = Simulator::new(stage.layer, Platform::new(acc))
                            .with_batch(batch)
                            .run(&stage.strategy)
                            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
                        assert_eq!(r.dma_busy_per.len(), k);
                        assert_eq!(r.compute_busy_per.len(), m);
                        assert_eq!(r.dma_busy_per.iter().sum::<u64>(), r.dma_busy);
                        assert_eq!(
                            r.compute_busy_per.iter().sum::<u64>(),
                            r.compute_busy
                        );
                        let floor = r
                            .dma_busy
                            .div_ceil(k as u64)
                            .max(r.compute_busy.div_ceil(m as u64));
                        assert!(
                            r.duration >= floor,
                            "seed {seed} stage {} {k}x{m} batch {batch}: \
                             makespan {} below floor {floor}",
                            stage.name,
                            r.duration
                        );
                        assert!(
                            r.duration <= r.sequential_duration,
                            "seed {seed} stage {} {k}x{m} batch {batch}: \
                             makespan {} above sequential {}",
                            stage.name,
                            r.duration,
                            r.sequential_duration
                        );
                        grid[k - 1][m - 1] = r.duration;
                    }
                }
                for k in 1..=3usize {
                    for m in 1..=3usize {
                        if k > 1 {
                            assert!(
                                grid[k - 1][m - 1] <= grid[k - 2][m - 1],
                                "seed {seed} stage {} batch {batch}: \
                                 makespan rose {}x{m} -> {k}x{m}",
                                stage.name,
                                k - 1
                            );
                        }
                        if m > 1 {
                            assert!(
                                grid[k - 1][m - 1] <= grid[k - 1][m - 2],
                                "seed {seed} stage {} batch {batch}: \
                                 makespan rose {k}x{} -> {k}x{m}",
                                stage.name,
                                m - 1
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn memory_capacity_and_coverage_invariants() {
    let cfg = Config { cases: 120, ..Default::default() };
    check(&cfg, gen_scenario, shrink_scenario, |s| {
        let acc = accelerator_for(s);
        let report = Simulator::new(s.layer, Platform::new(acc))
            .run(&s.strategy)
            .map_err(|e| format!("simulation failed: {e}"))?;
        // (1) peak within capacity (the simulator would error otherwise,
        //     but assert the report agrees)
        if report.peak_occupancy > acc.size_mem {
            return Err(format!(
                "peak {} exceeds capacity {}",
                report.peak_occupancy, acc.size_mem
            ));
        }
        // (2,3) validation: all patches once, memory empty, outputs written
        let v = strategy::validate(&s.layer, &acc, &s.strategy, u32::MAX);
        if !v.is_valid() {
            return Err(format!("violations: {:?}", v.violations));
        }
        Ok(())
    });
}

#[test]
fn functional_simulation_matches_reference() {
    let cfg = Config { cases: 60, ..Default::default() };
    check(&cfg, gen_scenario, shrink_scenario, |s| {
        let acc = accelerator_for(s);
        let sim = Simulator::new(s.layer, Platform::new(acc));
        let input = convoffload::conv::reference::synth_tensor(
            s.layer.input_dims().len(),
            0xFEED,
        );
        let kernels = convoffload::conv::reference::synth_tensor(
            s.layer.kernel_elements(),
            0xBEEF,
        );
        let mut backend = RustOracleBackend;
        let report = sim
            .run_functional(&s.strategy, &input, &kernels, &mut backend)
            .map_err(|e| format!("functional failed: {e}"))?;
        match report.functional_ok(1e-4) {
            Some(true) => Ok(()),
            other => Err(format!(
                "functional mismatch: {other:?}, err={:?}",
                report.max_abs_error
            )),
        }
    });
}

#[test]
fn simulator_duration_equals_fast_objective() {
    let cfg = Config { cases: 80, ..Default::default() };
    check(&cfg, gen_scenario, shrink_scenario, |s| {
        let mut acc = accelerator_for(s);
        acc.t_w = 0; // the fast objective charges writes as a constant term
        let report = Simulator::new(s.layer, Platform::new(acc))
            .run(&s.strategy)
            .map_err(|e| format!("simulation failed: {e}"))?;
        let fast = grouping_duration(&s.layer, &acc, &s.strategy.groups);
        let kernel_load = (s.layer.kernel_elements() as u64) * acc.t_l;
        if report.duration != fast + kernel_load {
            return Err(format!(
                "sim duration {} != objective {} + kernel load {}",
                report.duration, fast, kernel_load
            ));
        }
        Ok(())
    });
}

#[test]
fn serialization_roundtrips_preserve_strategy() {
    let cfg = Config { cases: 60, ..Default::default() };
    check(&cfg, gen_scenario, shrink_scenario, |s| {
        let csv = strategy_to_csv(&s.strategy);
        let from_csv = strategy_from_csv("rt", &csv).map_err(|e| e.to_string())?;
        if from_csv.groups != s.strategy.groups {
            return Err("CSV round-trip changed groups".to_string());
        }
        let json = strategy_to_json(&s.strategy);
        let from_json = strategy_from_json(&json).map_err(|e| e.to_string())?;
        if from_json.groups != s.strategy.groups
            || from_json.writeback != s.strategy.writeback
        {
            return Err("JSON round-trip changed strategy".to_string());
        }
        Ok(())
    });
}

/// The analytic overlap machinery must agree with brute-force `PixelSet`
/// intersections on every random generalized layer: `patch_overlap` (the
/// dilated-lattice closed form), the sparse graph's edge sizes, and the
/// closed-form degree bound.
#[test]
fn analytic_overlaps_match_brute_force() {
    let cfg = Config { cases: 80, ..Default::default() };
    check(
        &cfg,
        gen_scenario,
        shrink_scenario,
        |s| {
            let l = &s.layer;
            let graph = OverlapGraph::build(l);
            if graph.max_degree() > OverlapGraph::degree_bound(l) {
                return Err(format!(
                    "degree {} exceeds bound {} on {l}",
                    graph.max_degree(),
                    OverlapGraph::degree_bound(l)
                ));
            }
            for a in l.all_patches() {
                let pa = l.patch_pixels(a);
                for b in l.all_patches() {
                    let brute = pa.intersection_len(&l.patch_pixels(b));
                    if a != b && graph.overlap(a, b) != brute {
                        return Err(format!(
                            "graph overlap({a},{b}) = {} but brute force = {brute} on {l}",
                            graph.overlap(a, b)
                        ));
                    }
                    let analytic = l.patch_overlap(a, b);
                    if analytic != brute {
                        return Err(format!(
                            "patch_overlap({a},{b}) = {analytic} but brute force = {brute} on {l}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Any grouping's total loaded pixels is bounded below by the layer's
/// distinct-pixel count (every needed pixel loads at least once) and above
/// by the sum of group footprints (overlap reuse never hurts).
#[test]
fn grouping_loads_respect_distinct_pixel_bounds() {
    let cfg = Config { cases: 80, ..Default::default() };
    check(&cfg, gen_scenario, shrink_scenario, |s| {
        let l = &s.layer;
        let all: Vec<u32> = l.all_patches().collect();
        let distinct = l.group_pixels(&all).len() as u64;
        let loads = grouping_loads(l, &s.strategy.groups);
        if loads < distinct {
            return Err(format!(
                "loads {loads} below the distinct-pixel lower bound {distinct} on {l}"
            ));
        }
        let upper: u64 = s
            .strategy
            .groups
            .iter()
            .map(|g| l.group_pixels(g).len() as u64)
            .sum();
        if loads > upper {
            return Err(format!(
                "loads {loads} above the footprint-sum upper bound {upper} on {l}"
            ));
        }
        Ok(())
    });
}

/// Every strategy the network fuzzer emits passes full §2.3 validation on
/// its own accelerator — the generator's "valid by construction" contract.
#[test]
fn fuzz_network_strategies_validate() {
    for seed in 0..60u64 {
        let net = fuzz::random_network(seed);
        for stage in &net.stages {
            let report = strategy::validate(
                &stage.layer,
                &stage.accelerator,
                &stage.strategy,
                u32::MAX,
            );
            assert!(
                report.is_valid(),
                "seed {seed} stage {}: {:?}",
                stage.name,
                report.violations
            );
        }
    }
}

#[test]
fn pixel_loads_bounded_by_runs() {
    // Every pixel's load count equals its number of *runs* of consecutive
    // groups containing it — the quantity the ILP's pxl_I models (Eq. 8).
    let cfg = Config { cases: 60, ..Default::default() };
    check(&cfg, gen_scenario, shrink_scenario, |s| {
        let acc = accelerator_for(s);
        let v = strategy::validate(&s.layer, &acc, &s.strategy, u32::MAX);
        if !v.is_valid() {
            return Err(format!("violations: {:?}", v.violations));
        }
        // recompute runs per pixel from the groups
        let k = s.strategy.groups.len();
        let mut in_group = vec![vec![false; k]; s.layer.n_pixels()];
        for (gi, g) in s.strategy.groups.iter().enumerate() {
            for px in s.layer.group_pixels(g).iter() {
                in_group[px as usize][gi] = true;
            }
        }
        for (px, loads) in v.pixel_loads.iter().enumerate() {
            let mut runs = 0u32;
            let mut prev = false;
            for &now in &in_group[px] {
                if now && !prev {
                    runs += 1;
                }
                prev = now;
            }
            if runs != *loads {
                return Err(format!(
                    "pixel {px}: {loads} loads but {runs} runs"
                ));
            }
        }
        Ok(())
    });
}

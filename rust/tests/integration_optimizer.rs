//! Integration tests over the optimizer stack: the three engines must agree
//! where their domains overlap, and optimized strategies must simulate
//! correctly end to end.

use std::time::Duration;

use convoffload::config::presets::paper_sweep_layer;
use convoffload::optimizer::{
    build_s1_model, decode_solution, exact, grouping_loads,
    model_builder::encode_mip_start, OptimizeOptions, Optimizer,
};
use convoffload::platform::{Accelerator, Platform};
use convoffload::sim::{RustOracleBackend, Simulator};
use convoffload::solver::{solve_milp, BranchBoundOptions};
use convoffload::strategy;

/// The generic §5 MILP and the specialized exact DFS must find the same
/// optimum on every tractable (layer, group) pair.
#[test]
fn milp_and_exact_dfs_agree_on_small_grid() {
    // Exact agreement where the generic dense-simplex MILP is tractable…
    for (h_in, g) in [(4usize, 2usize), (4, 3)] {
        let layer = paper_sweep_layer(h_in);
        let acc = Accelerator::for_group_size(&layer, g);
        let k = acc.k_min(&layer);

        let (model, info) = build_s1_model(&layer, &acc, k, 4);
        let start = strategy::row_by_row(&layer, g);
        let x0 = encode_mip_start(&layer, &info, &start.groups, model.n_vars());
        let sol = solve_milp(
            &model,
            &BranchBoundOptions {
                mip_start: Some(x0),
                time_budget: Duration::from_secs(180),
                node_budget: 500_000,
                ..Default::default()
            },
        );
        assert_eq!(
            sol.status,
            convoffload::ilp::SolveStatus::Optimal,
            "h={h_in} g={g}"
        );
        let milp_loads =
            grouping_loads(&layer, &decode_solution(&info, &sol.assignment).groups);

        let dfs = exact::solve_exact(&layer, g, k, Duration::from_secs(60), None)
            .expect("exact finishes");
        let dfs_loads = grouping_loads(&layer, &dfs);
        assert_eq!(milp_loads, dfs_loads, "h={h_in} g={g}");
    }
}

/// Where the generic MILP hits its budget (exactly the regime in which the
/// paper's CPLEX ran into its 0.5–5 h timeouts), the incumbent must still
/// bracket correctly: MIP-start ≥ incumbent ≥ exact optimum ≥ LP bound.
#[test]
fn milp_incumbent_brackets_on_budget_exhaustion() {
    let layer = paper_sweep_layer(5); // 9 patches
    let g = 4;
    let acc = Accelerator::for_group_size(&layer, g);
    let k = acc.k_min(&layer);

    let (model, info) = build_s1_model(&layer, &acc, k, 4);
    let start = strategy::row_by_row(&layer, g);
    let start_loads = grouping_loads(&layer, &start.groups) as f64;
    let x0 = encode_mip_start(&layer, &info, &start.groups, model.n_vars());
    let sol = solve_milp(
        &model,
        &BranchBoundOptions {
            mip_start: Some(x0),
            time_budget: Duration::from_secs(20),
            node_budget: 3_000,
            ..Default::default()
        },
    );
    assert!(
        matches!(
            sol.status,
            convoffload::ilp::SolveStatus::Feasible
                | convoffload::ilp::SolveStatus::Optimal
        ),
        "{:?}",
        sol.status
    );
    let incumbent =
        grouping_loads(&layer, &decode_solution(&info, &sol.assignment).groups) as f64;
    let exact_opt = grouping_loads(
        &layer,
        &exact::solve_exact(&layer, g, k, Duration::from_secs(60), None).unwrap(),
    ) as f64;
    assert!(incumbent <= start_loads + 1e-9);
    assert!(incumbent >= exact_opt - 1e-9);
    assert!(sol.lower_bound <= exact_opt + 1e-9);
}

/// The annealer must reach the proven optimum on instances the exact engine
/// can certify.
#[test]
fn polish_reaches_exact_optimum_on_small_instances() {
    for (h_in, g) in [(5usize, 2usize), (5, 3), (6, 4)] {
        let layer = paper_sweep_layer(h_in);
        let k = layer.n_patches().div_ceil(g);
        let optimal = exact::solve_exact(&layer, g, k, Duration::from_secs(120), None)
            .expect("exact finishes");
        let optimal_loads = grouping_loads(&layer, &optimal);

        let start = strategy::row_by_row(&layer, g).groups;
        let polished = convoffload::optimizer::search::anneal(
            &layer, g, k, &start, 300_000, 0xDEAD,
        );
        let polished_loads = grouping_loads(&layer, &polished);
        assert_eq!(
            polished_loads, optimal_loads,
            "h={h_in} g={g}: annealer stuck at {polished_loads} vs optimum {optimal_loads}"
        );
    }
}

/// Optimized strategies must pass full simulation (semantics + §2.3 checks
/// with the run-count bound) and functional correctness.
#[test]
fn optimized_strategies_simulate_and_compute_correctly() {
    for h_in in [6usize, 9] {
        let layer = paper_sweep_layer(h_in);
        let g = 4;
        let acc = Accelerator::for_group_size(&layer, g);
        let res = Optimizer::new(OptimizeOptions {
            group_size: g,
            anneal_iters: 60_000,
            ..Default::default()
        })
        .optimize(&layer, &acc);

        let sim = Simulator::new(layer, Platform::new(acc));
        let input =
            convoffload::conv::reference::synth_tensor(layer.input_dims().len(), 7);
        let kernels =
            convoffload::conv::reference::synth_tensor(layer.kernel_elements(), 8);
        let mut backend = RustOracleBackend;
        let report = sim
            .run_functional(&res.strategy, &input, &kernels, &mut backend)
            .expect("optimized strategy must simulate");
        assert_eq!(report.functional_ok(1e-4), Some(true));
        // reported duration matches the simulator's (modulo kernel load)
        let kernel_load = layer.kernel_elements() as u64 * acc.t_l;
        assert_eq!(report.duration, res.duration + kernel_load);
    }
}

/// Gain structure across the Fig. 13 grid corners (paper's two regions).
#[test]
fn gain_regions() {
    // upper-right: group ≥ |X| → everything in one group → no gain possible
    let layer = paper_sweep_layer(4); // 4 patches
    let acc = Accelerator::for_group_size(&layer, 4);
    let res = Optimizer::new(OptimizeOptions { group_size: 4, ..Default::default() })
        .optimize(&layer, &acc);
    assert_eq!(res.gain_over_heuristics(), 0.0);

    // lower-left: small groups on a 10x10 → positive gain (paper: up to 30%)
    let layer = paper_sweep_layer(10);
    let acc = Accelerator::for_group_size(&layer, 2);
    let res = Optimizer::new(OptimizeOptions {
        group_size: 2,
        anneal_iters: 120_000,
        ..Default::default()
    })
    .optimize(&layer, &acc);
    assert!(
        res.gain_over_heuristics() > 0.05,
        "expected a clear gain, got {:.2}%",
        res.gain_over_heuristics() * 100.0
    );
}

/// `k_groups` override: forcing more groups than K_min costs extra t_acc
/// (and can never reduce loads below the K_min optimum's).
#[test]
fn k_groups_override_respected() {
    let layer = paper_sweep_layer(5);
    let g = 3;
    let acc = Accelerator::for_group_size(&layer, g);
    let kmin_res = Optimizer::new(OptimizeOptions {
        group_size: g,
        ..Default::default()
    })
    .optimize(&layer, &acc);
    let more_groups = Optimizer::new(OptimizeOptions {
        group_size: g,
        k_groups: Some(layer.n_patches()), // one patch per group
        ..Default::default()
    })
    .optimize(&layer, &acc);
    assert_eq!(more_groups.strategy.groups.len(), layer.n_patches());
    assert!(more_groups.duration >= kmin_res.duration);
}

/// Reload-bound interaction: the §5 model at `nb_data_reload = 1` forbids
/// any pixel reload; on a layer whose optimal grouping needs reloads this
/// must tighten the optimum (or go infeasible), never loosen it.
#[test]
fn reload_bound_tightens_the_milp() {
    let layer = paper_sweep_layer(4);
    let acc = Accelerator::for_group_size(&layer, 2);
    let k = acc.k_min(&layer);
    let (loose_model, _) = build_s1_model(&layer, &acc, k, 4);
    let (tight_model, _) = build_s1_model(&layer, &acc, k, 1);
    let loose = solve_milp(&loose_model, &BranchBoundOptions::default());
    let tight = solve_milp(&tight_model, &BranchBoundOptions::default());
    assert_eq!(loose.status, convoffload::ilp::SolveStatus::Optimal);
    match tight.status {
        convoffload::ilp::SolveStatus::Optimal => {
            assert!(tight.objective >= loose.objective - 1e-9);
        }
        convoffload::ilp::SolveStatus::Infeasible => {} // also acceptable
        other => panic!("unexpected status {other:?}"),
    }
}

//! Pinned end-to-end tests for the solving substrate: `solver::simplex` on
//! hand-computed LPs (optimal / degenerate / infeasible / unbounded),
//! `solver::branch_bound` on hand-solved 0-1 programs, and the boolean
//! linearization gadgets of `ilp` driven through a real MILP solve rather
//! than feasibility checks alone.

use std::time::Duration;

use convoffload::ilp::{
    linearize_and, linearize_and_not, linearize_or, BoolVar, Cmp, LinExpr, Model,
    SolveStatus, VarKind,
};
use convoffload::solver::{solve_lp, solve_milp, BranchBoundOptions, LpOutcome};

// ---------------------------------------------------------------- simplex

/// The Dantzig textbook LP: max 3x + 5y s.t. x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18.
/// Optimum (2, 6) with value 36 — minimized here as −3x − 5y = −36.
#[test]
fn simplex_pins_the_textbook_optimum() {
    let mut m = Model::minimize();
    let x = m.var("x", 0.0, f64::INFINITY, VarKind::Continuous);
    let y = m.var("y", 0.0, f64::INFINITY, VarKind::Continuous);
    m.constrain(LinExpr::term(x, 1.0), Cmp::Le, 4.0);
    m.constrain(LinExpr::term(y, 2.0), Cmp::Le, 12.0);
    let mut row = LinExpr::new();
    row.add(x, 3.0).add(y, 2.0);
    m.constrain(row, Cmp::Le, 18.0);
    let mut obj = LinExpr::new();
    obj.add(x, -3.0).add(y, -5.0);
    m.set_objective(obj);

    match solve_lp(&m, &[]) {
        LpOutcome::Optimal { assignment, objective } => {
            assert!((objective + 36.0).abs() < 1e-9, "{objective}");
            assert!((assignment[x.0] - 2.0).abs() < 1e-9);
            assert!((assignment[y.0] - 6.0).abs() < 1e-9);
        }
        other => panic!("expected optimal, got {other:?}"),
    }
}

/// Mixed `=` / `≥` rows exercise the phase-1 artificial machinery:
/// min 2x + 3y s.t. x + y = 4, x ≤ 1 → (1, 3) with value 11.
#[test]
fn simplex_handles_equality_and_bound_rows() {
    let mut m = Model::minimize();
    let x = m.var("x", 0.0, f64::INFINITY, VarKind::Continuous);
    let y = m.var("y", 0.0, f64::INFINITY, VarKind::Continuous);
    let mut eq = LinExpr::new();
    eq.add(x, 1.0).add(y, 1.0);
    m.constrain(eq, Cmp::Eq, 4.0);
    m.constrain(LinExpr::term(x, 1.0), Cmp::Le, 1.0);
    let mut obj = LinExpr::new();
    obj.add(x, 2.0).add(y, 3.0);
    m.set_objective(obj);

    match solve_lp(&m, &[]) {
        LpOutcome::Optimal { assignment, objective } => {
            assert!((objective - 11.0).abs() < 1e-9, "{objective}");
            assert!((assignment[x.0] - 1.0).abs() < 1e-9);
            assert!((assignment[y.0] - 3.0).abs() < 1e-9);
        }
        other => panic!("expected optimal, got {other:?}"),
    }
}

/// A degenerate vertex (more tight rows than dimensions at the optimum):
/// the Bland's-rule fallback must still terminate at −2 on (1, 1).
#[test]
fn simplex_terminates_on_a_degenerate_vertex() {
    let mut m = Model::minimize();
    let x = m.var("x", 0.0, f64::INFINITY, VarKind::Continuous);
    let y = m.var("y", 0.0, f64::INFINITY, VarKind::Continuous);
    m.constrain(LinExpr::term(x, 1.0), Cmp::Le, 1.0);
    m.constrain(LinExpr::term(y, 1.0), Cmp::Le, 1.0);
    // Redundant rows all tight at the optimum (1, 1).
    for _ in 0..3 {
        let mut row = LinExpr::new();
        row.add(x, 1.0).add(y, 1.0);
        m.constrain(row, Cmp::Le, 2.0);
    }
    let mut obj = LinExpr::new();
    obj.add(x, -1.0).add(y, -1.0);
    m.set_objective(obj);

    match solve_lp(&m, &[]) {
        LpOutcome::Optimal { objective, .. } => {
            assert!((objective + 2.0).abs() < 1e-9, "{objective}");
        }
        other => panic!("expected optimal, got {other:?}"),
    }
}

#[test]
fn simplex_detects_infeasibility() {
    let mut m = Model::minimize();
    let x = m.var("x", 0.0, 1.0, VarKind::Continuous);
    let y = m.var("y", 0.0, 1.0, VarKind::Continuous);
    let mut row = LinExpr::new();
    row.add(x, 1.0).add(y, 1.0);
    m.constrain(row, Cmp::Ge, 3.0); // x + y ≤ 2 by bounds
    m.set_objective(LinExpr::term(x, 1.0));
    assert_eq!(solve_lp(&m, &[]), LpOutcome::Infeasible);
}

#[test]
fn simplex_detects_unboundedness() {
    let mut m = Model::minimize();
    let x = m.var("x", 0.0, f64::INFINITY, VarKind::Continuous);
    m.constrain(LinExpr::term(x, 1.0), Cmp::Ge, 1.0);
    m.set_objective(LinExpr::term(x, -1.0)); // −x → −∞ as x grows
    assert_eq!(solve_lp(&m, &[]), LpOutcome::Unbounded);
}

/// Bound overrides (the branch & bound fixing mechanism) restrict the same
/// model without rebuilding it.
#[test]
fn simplex_bound_overrides_fix_variables() {
    let mut m = Model::minimize();
    let x = m.var("x", 0.0, 10.0, VarKind::Continuous);
    let y = m.var("y", 0.0, 10.0, VarKind::Continuous);
    let mut row = LinExpr::new();
    row.add(x, 1.0).add(y, 1.0);
    m.constrain(row, Cmp::Le, 10.0);
    let mut obj = LinExpr::new();
    obj.add(x, -1.0).add(y, -2.0);
    m.set_objective(obj);
    // Free: all budget on y → −20. With y fixed to 3: x = 7 → −13.
    match solve_lp(&m, &[]) {
        LpOutcome::Optimal { objective, .. } => assert!((objective + 20.0).abs() < 1e-9),
        other => panic!("{other:?}"),
    }
    match solve_lp(&m, &[None, Some((3.0, 3.0))]) {
        LpOutcome::Optimal { assignment, objective } => {
            assert!((objective + 13.0).abs() < 1e-9, "{objective}");
            assert!((assignment[y.0] - 3.0).abs() < 1e-9);
        }
        other => panic!("{other:?}"),
    }
}

// ---------------------------------------------------------------- branch & bound

/// 0-1 knapsack with values (8, 11, 6, 4), weights (5, 7, 4, 3), capacity
/// 14. Hand enumeration: {b, c, d} fits exactly (7+4+3 = 14) at value 21;
/// every other feasible subset is worth less.
fn knapsack_8_11_6_4() -> (Model, Vec<BoolVar>) {
    let values = [8.0, 11.0, 6.0, 4.0];
    let weights = [5.0, 7.0, 4.0, 3.0];
    let mut m = Model::minimize();
    let vars: Vec<BoolVar> =
        (0..4).map(|i| m.bool_var(&format!("x{i}"))).collect();
    let mut w = LinExpr::new();
    let mut obj = LinExpr::new();
    for (i, v) in vars.iter().enumerate() {
        w.add(v.0, weights[i]);
        obj.add(v.0, -values[i]);
    }
    m.constrain(w, Cmp::Le, 14.0);
    m.set_objective(obj);
    (m, vars)
}

#[test]
fn branch_bound_pins_a_hand_solved_knapsack() {
    let (m, vars) = knapsack_8_11_6_4();
    let sol = solve_milp(&m, &BranchBoundOptions::default());
    assert_eq!(sol.status, SolveStatus::Optimal);
    assert!((sol.objective + 21.0).abs() < 1e-6, "{}", sol.objective);
    assert!(sol.lower_bound <= sol.objective + 1e-6);
    let picks: Vec<bool> =
        vars.iter().map(|v| sol.assignment[v.0 .0] > 0.5).collect();
    assert_eq!(picks, vec![false, true, true, true]);
}

/// 3×3 assignment problem with cost matrix rows (4,2,8), (4,3,7), (3,1,6).
/// The six permutations cost 13, 12, 12, 12, 13, 14 — optimum 12.
#[test]
fn branch_bound_pins_a_hand_solved_assignment() {
    let cost = [[4.0, 2.0, 8.0], [4.0, 3.0, 7.0], [3.0, 1.0, 6.0]];
    let mut m = Model::minimize();
    let mut x = Vec::new();
    for i in 0..3 {
        let row: Vec<BoolVar> =
            (0..3).map(|j| m.bool_var(&format!("x{i}{j}"))).collect();
        x.push(row);
    }
    let mut obj = LinExpr::new();
    for i in 0..3 {
        for j in 0..3 {
            obj.add(x[i][j].0, cost[i][j]);
        }
    }
    m.set_objective(obj);
    for i in 0..3 {
        let mut row = LinExpr::new();
        let mut col = LinExpr::new();
        for j in 0..3 {
            row.add(x[i][j].0, 1.0);
            col.add(x[j][i].0, 1.0);
        }
        m.constrain(row, Cmp::Eq, 1.0);
        m.constrain(col, Cmp::Eq, 1.0);
    }
    let sol = solve_milp(&m, &BranchBoundOptions::default());
    assert_eq!(sol.status, SolveStatus::Optimal);
    assert!((sol.objective - 12.0).abs() < 1e-6, "{}", sol.objective);
}

#[test]
fn branch_bound_reports_infeasible_binary_models() {
    let mut m = Model::minimize();
    let a = m.bool_var("a");
    let b = m.bool_var("b");
    let mut row = LinExpr::new();
    row.add(a.0, 1.0).add(b.0, 1.0);
    m.constrain(row, Cmp::Ge, 3.0);
    m.set_objective(LinExpr::term(a.0, 1.0));
    let sol = solve_milp(&m, &BranchBoundOptions::default());
    assert_eq!(sol.status, SolveStatus::Infeasible);
    assert!(sol.assignment.is_empty());
}

/// An exhausted node budget returns the MIP-start incumbent as `Feasible` —
/// never a hang, never a false `Optimal`.
#[test]
fn branch_bound_budget_exhaustion_keeps_the_incumbent() {
    let (m, _) = knapsack_8_11_6_4();
    let start = vec![1.0, 0.0, 0.0, 0.0]; // greedy pick: value 8, weight 5
    let sol = solve_milp(
        &m,
        &BranchBoundOptions {
            node_budget: 0,
            mip_start: Some(start.clone()),
            ..BranchBoundOptions::default()
        },
    );
    assert_eq!(sol.status, SolveStatus::Feasible);
    assert_eq!(sol.nodes, 0);
    assert!((sol.objective + 8.0).abs() < 1e-6, "{}", sol.objective);
    assert_eq!(sol.assignment, start);
}

/// An infeasible MIP start is ignored rather than trusted.
#[test]
fn branch_bound_rejects_an_infeasible_mip_start() {
    let (m, _) = knapsack_8_11_6_4();
    let sol = solve_milp(
        &m,
        &BranchBoundOptions {
            mip_start: Some(vec![1.0, 1.0, 1.0, 1.0]), // weight 19 > 14
            ..BranchBoundOptions::default()
        },
    );
    assert_eq!(sol.status, SolveStatus::Optimal);
    assert!((sol.objective + 21.0).abs() < 1e-6);
}

/// A zero time budget with no start yields a clean `Unknown`, not a panic
/// or a bogus answer.
#[test]
fn branch_bound_zero_budget_without_start_is_unknown() {
    let (m, _) = knapsack_8_11_6_4();
    let sol = solve_milp(
        &m,
        &BranchBoundOptions {
            time_budget: Duration::from_secs(0),
            node_budget: 0,
            ..BranchBoundOptions::default()
        },
    );
    assert_eq!(sol.status, SolveStatus::Unknown);
    assert!(sol.assignment.is_empty());
}

// ---------------------------------------------------------------- gadgets

/// Drive each boolean gadget through a real MILP solve: force the inputs
/// with equality constraints, minimize ±out, and check the solved value
/// equals the gate — the linearizations must pin `out` exactly, not merely
/// admit it.
fn solved_gate_value(
    build: impl Fn(&mut Model, BoolVar, &[BoolVar]),
    inputs: &[f64],
    maximize_out: bool,
) -> f64 {
    let mut m = Model::minimize();
    let ins: Vec<BoolVar> = (0..inputs.len())
        .map(|i| m.bool_var(&format!("v{i}")))
        .collect();
    let out = m.bool_var("out");
    build(&mut m, out, &ins);
    for (v, &val) in ins.iter().zip(inputs) {
        m.constrain(LinExpr::term(v.0, 1.0), Cmp::Eq, val);
    }
    let sign = if maximize_out { -1.0 } else { 1.0 };
    m.set_objective(LinExpr::term(out.0, sign));
    let sol = solve_milp(&m, &BranchBoundOptions::default());
    assert_eq!(sol.status, SolveStatus::Optimal);
    sol.assignment[out.0 .0]
}

#[test]
fn linearize_or_pins_out_under_milp() {
    for mask in 0..8u32 {
        let inputs: Vec<f64> = (0..3).map(|i| ((mask >> i) & 1) as f64).collect();
        let expect = if mask != 0 { 1.0 } else { 0.0 };
        for maximize in [false, true] {
            let got = solved_gate_value(
                |m, out, ins| linearize_or(m, out, ins),
                &inputs,
                maximize,
            );
            assert!((got - expect).abs() < 1e-6, "mask {mask:b}, max {maximize}");
        }
    }
}

#[test]
fn linearize_and_pins_out_under_milp() {
    for mask in 0..4u32 {
        let inputs: Vec<f64> = (0..2).map(|i| ((mask >> i) & 1) as f64).collect();
        let expect = if mask == 3 { 1.0 } else { 0.0 };
        for maximize in [false, true] {
            let got = solved_gate_value(
                |m, out, ins| linearize_and(m, out, ins[0], ins[1]),
                &inputs,
                maximize,
            );
            assert!((got - expect).abs() < 1e-6, "mask {mask:b}, max {maximize}");
        }
    }
}

#[test]
fn linearize_and_not_pins_out_under_milp() {
    for mask in 0..4u32 {
        let inputs: Vec<f64> = (0..2).map(|i| ((mask >> i) & 1) as f64).collect();
        let expect = if mask == 1 { 1.0 } else { 0.0 }; // a ∧ ¬b
        for maximize in [false, true] {
            let got = solved_gate_value(
                |m, out, ins| linearize_and_not(m, out, ins[0], ins[1]),
                &inputs,
                maximize,
            );
            assert!((got - expect).abs() < 1e-6, "mask {mask:b}, max {maximize}");
        }
    }
}

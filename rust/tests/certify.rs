//! Certification property suite (mirrored in Python by
//! `python/tests/test_certify_oracle.py`):
//!
//! * the analytic communication floor never exceeds what any simulated run
//!   actually loads — across all 24 differential fuzz seeds, both overlap
//!   modes, every (k, m) ∈ {1, 2}² resource shape and the sampled image
//!   batches;
//! * the floor is monotone non-increasing in `size_MEM`;
//! * planner winners respect the pixel-domain floor on the preset zoo;
//! * both lenet5-scale micro stages certify **exactly** at group 2: the
//!   budgeted branch & bound proves the portfolio winner optimal (gap 0)
//!   and the independent §5 MILP lands on the same optimum.

use convoffload::config::fuzz::random_network;
use convoffload::config::network_preset;
use convoffload::planner::{
    certify_network, comm_lower_bound, optimality_gap, AcceleratorSpec, CertifyOptions,
    ExactStatus, NetworkPlanner, PlanOptions,
};
use convoffload::platform::{Accelerator, OverlapMode, Platform};
use convoffload::sim::Simulator;

/// The fuzz seeds shared with the differential harness.
const SEEDS: std::ops::RangeInclusive<u64> = 1..=24;

/// Element-domain floor ≤ simulated loads, for every fuzz stage under every
/// overlap mode × resource shape × the network's sampled batch.
#[test]
fn bound_is_a_true_floor_across_the_fuzz_corpus() {
    for seed in SEEDS {
        let net = random_network(seed);
        for overlap in [OverlapMode::Sequential, OverlapMode::DoubleBuffered] {
            for (k, m) in [(1, 1), (1, 2), (2, 1), (2, 2)] {
                for batch in [1, net.batch] {
                    for s in &net.stages {
                        let acc = s
                            .accelerator
                            .with_overlap(overlap)
                            .with_channels(k, m);
                        let r = Simulator::new(s.layer, Platform::new(acc))
                            .with_batch(batch)
                            .run(&s.strategy)
                            .unwrap_or_else(|e| {
                                panic!("seed {seed} stage {}: {e}", s.name)
                            });
                        assert!(
                            r.comm_lower_bound <= r.totals.total.loaded_elements,
                            "seed {seed} stage {} ({overlap:?} {k}x{m} b{batch}): \
                             floor {} above loads {}",
                            s.name,
                            r.comm_lower_bound,
                            r.totals.total.loaded_elements,
                        );
                        assert!(r.comm_lower_bound > 0, "floor must be nontrivial");
                        assert_eq!(
                            r.optimality_gap,
                            optimality_gap(
                                r.totals.total.loaded_elements,
                                r.comm_lower_bound
                            )
                        );
                    }
                }
            }
        }
    }
}

/// More memory can only lower (never raise) the floor — the 1911.05662
/// monotonicity property, checked on every fuzz layer.
#[test]
fn bound_is_monotone_non_increasing_in_size_mem() {
    for seed in SEEDS {
        let net = random_network(seed);
        for s in &net.stages {
            let mut prev = u64::MAX;
            for scale in [0u64, 1, 2, 4, 16, 1024] {
                let acc = Accelerator {
                    size_mem: s.accelerator.size_mem.saturating_mul(scale),
                    ..s.accelerator
                };
                let b = comm_lower_bound(&s.layer, &acc);
                assert!(
                    b.bound_pixels <= prev,
                    "seed {seed} stage {}: bound grew at scale {scale}",
                    s.name
                );
                prev = b.bound_pixels;
            }
        }
    }
}

/// Planner winners respect the pixel-domain floor on the whole preset zoo,
/// in both overlap modes, and the plan-level aggregates are consistent.
#[test]
fn planner_winners_respect_the_floor_on_the_preset_zoo() {
    for name in ["lenet5", "resnet8", "mobilenet_slim"] {
        let preset = network_preset(name).unwrap();
        for overlap in [OverlapMode::Sequential, OverlapMode::DoubleBuffered] {
            let planner = NetworkPlanner::new(PlanOptions {
                anneal_iters: 500,
                anneal_starts: 1,
                overlap,
                ..PlanOptions::default()
            });
            let plan = planner.plan(&preset).unwrap();
            let mut total = 0u64;
            let mut worst = 0.0f64;
            for lp in &plan.layers {
                assert!(lp.comm_lower_bound > 0, "{name}/{}", lp.stage);
                assert!(
                    lp.comm_lower_bound <= lp.loaded_pixels,
                    "{name}/{}: floor {} above winner {}",
                    lp.stage,
                    lp.comm_lower_bound,
                    lp.loaded_pixels
                );
                assert_eq!(
                    lp.optimality_gap,
                    optimality_gap(lp.loaded_pixels, lp.comm_lower_bound),
                    "{name}/{}",
                    lp.stage
                );
                total += lp.comm_lower_bound;
                worst = worst.max(lp.optimality_gap);
            }
            assert_eq!(plan.total_comm_lower_bound, total, "{name}");
            assert_eq!(plan.worst_optimality_gap, worst, "{name}");
        }
    }
}

/// The acceptance-bar certification: both lenet5_micro stages (the LeNet-5
/// trunk at 4-patch scale) are proven optimal at group 2 — the specialized
/// search completes, the winner matches the exact optimum (gap 0 against
/// the achieved loads), and the independent §5 MILP agrees on stage shapes
/// small enough for it.
#[test]
fn lenet5_micro_certifies_exactly_at_group_two() {
    let preset = network_preset("lenet5_micro").unwrap();
    let planner = NetworkPlanner::new(PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(2),
        anneal_iters: 500,
        anneal_starts: 1,
        ..PlanOptions::default()
    });
    let plan = planner.plan(&preset).unwrap();
    let report = certify_network(
        &plan,
        &CertifyOptions { exact: true, ..CertifyOptions::default() },
    );
    assert_eq!(report.stages.len(), 2);
    assert_eq!(report.certified_exactly, 2, "both stages must certify");

    // Pinned floors: c1 = |U| of a 5x5 kernel on 6x6 (all 36 pixels);
    // c2 = |U| of a 3x3 kernel on 4x4 (all 16 pixels).
    let pinned = [("c1", 36u64), ("c2", 16u64)];
    for (s, (name, bound)) in report.stages.iter().zip(pinned) {
        assert_eq!(s.stage, name);
        assert_eq!(s.bound.bound_pixels, bound, "{name}");
        assert_eq!(s.exact_status, ExactStatus::Certified, "{name}");
        assert_eq!(s.exact_optimum, Some(bound), "{name}: optimum is the floor");
        assert_eq!(s.achieved_pixels, bound, "{name}: winner achieves it");
        assert_eq!(s.optimality_gap, 0.0, "{name}");
        assert_eq!(s.exact_matches_winner, Some(true), "{name}");
        assert_eq!(
            s.ilp_agrees,
            Some(true),
            "{name}: the independent MILP must land on the same optimum"
        );
        assert!(s.exact_nodes > 0, "{name}: the search actually ran");
    }
    assert_eq!(report.worst_gap, 0.0);
}

/// An exhausted node budget yields a clean `Unsolved` — the certify path
/// can never hang CI — while bound-only certification still stands.
#[test]
fn exhausted_budget_is_a_clean_unsolved() {
    let preset = network_preset("lenet5_micro").unwrap();
    let planner = NetworkPlanner::new(PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(2),
        anneal_iters: 500,
        anneal_starts: 1,
        ..PlanOptions::default()
    });
    let plan = planner.plan(&preset).unwrap();
    let report = certify_network(
        &plan,
        &CertifyOptions { exact: true, node_budget: 0, ..CertifyOptions::default() },
    );
    for s in &report.stages {
        assert_eq!(s.exact_status, ExactStatus::Unsolved, "{}", s.stage);
        assert_eq!(s.exact_optimum, None, "{}", s.stage);
        assert!(s.bound.bound_pixels > 0, "bound-only result survives");
    }
    assert_eq!(report.certified_exactly, 0);

    // Bound-only mode (the default) skips the exact path entirely.
    let bound_only = certify_network(&plan, &CertifyOptions::default());
    for s in &bound_only.stages {
        assert_eq!(s.exact_status, ExactStatus::Skipped, "{}", s.stage);
    }
}

/// Certification is read-only with respect to search: certifying a plan
/// leaves the plan bit-identical (same winners, loads, durations) to an
/// uncertified planning run with the same options.
#[test]
fn certification_does_not_perturb_the_plan() {
    let preset = network_preset("lenet5").unwrap();
    let options = || PlanOptions {
        anneal_iters: 500,
        anneal_starts: 1,
        ..PlanOptions::default()
    };
    let a = NetworkPlanner::new(options()).plan(&preset).unwrap();
    let _ = certify_network(&a, &CertifyOptions { exact: true, ..CertifyOptions::default() });
    let b = NetworkPlanner::new(options()).plan(&preset).unwrap();
    assert_eq!(a.total_duration, b.total_duration);
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        assert_eq!(la.winner, lb.winner);
        assert_eq!(la.loaded_pixels, lb.loaded_pixels);
        assert_eq!(la.duration, lb.duration);
    }
}

//! The Rust half of the Rust↔Python differential harness.
//!
//! Simulates a fixed set of fuzz networks (`config::fuzz::random_network`,
//! seeds 1..=24 — asserted below to cover stride > 1, dilation > 1,
//! groups > 1 and pooling) and writes the interchange file
//! `target/differential_cases.json` (version 5): every case carries the
//! full network spec (layers with dilation/groups, accelerators, explicit
//! strategy groups, plumbing flags) plus the Rust simulator's results under
//! **both** duration semantics — the sequential Definition-3 sums and the
//! §3.7 double-buffered makespans (on the case's own accelerator *and* on a
//! 2× memory "roomy" variant, where most residency checks pass so real
//! overlap is exercised) — plus a **fault-injected** replay of
//! the same network under a per-case [`FaultModel`] (DMA retries, timing
//! jitter, memory shrink), in both modes, with retry / shrink counts and
//! the analytic k-fault WCET bound. New in v4: each case samples a §3.10
//! resource shape (k DMA channels × m compute units) and an image batch and
//! records the multi-resource makespans with per-resource busy vectors, and
//! the faulted double-buffered replay of stage `i` draws from
//! `model.for_stage(i)` (stage-decorrelated streams). The Python oracle
//! (`python/oracle_sim.py`, exercised by
//! `python/tests/test_differential.py`) replays the specs — including the
//! seeded fault streams, via its own xoshiro256** port — independently and
//! asserts bit-equal durations, loaded elements, step counts, makespans and
//! fault accounting.
//!
//! CI runs this as part of tier-1 `cargo test`, uploads the JSON as an
//! artifact, and a dependent job replays it under pytest.

use std::path::PathBuf;

use convoffload::config::fuzz::{network_to_json, random_network, FuzzNetwork};
use convoffload::platform::{Accelerator, FaultModel, OverlapMode, Platform};
use convoffload::sim::Simulator;
use convoffload::util::json::Json;

/// Seed range shared with `fuzz::tests::seed_range_covers_all_feature_axes`
/// and the Python side (which just reads whatever the file contains).
const SEEDS: std::ops::RangeInclusive<u64> = 1..=24;

/// Workspace `target/` directory: the manifest dir is `<repo>/rust`, the
/// workspace target sits next to it.
fn target_dir() -> PathBuf {
    if let Ok(d) = std::env::var("CARGO_TARGET_DIR") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("manifest dir has a parent")
        .join("target")
}

/// Per-stage double-buffered replay of a fuzz network: the stage's own
/// accelerator switched to `DoubleBuffered`, with `extra_mem_factor`
/// scaling `size_mem` (1 = as sampled, 2 = the "roomy" variant).
fn overlapped_expectations(net: &FuzzNetwork, mem_factor: u64) -> Json {
    let mut per_stage: Vec<Json> = Vec::new();
    let mut total = 0u64;
    for s in &net.stages {
        let acc = Accelerator {
            size_mem: s.accelerator.size_mem * mem_factor,
            ..s.accelerator
        }
        .with_overlap(OverlapMode::DoubleBuffered);
        let r = Simulator::new(s.layer, Platform::new(acc))
            .run(&s.strategy)
            .unwrap_or_else(|e| {
                panic!("seed {} stage {}: overlapped sim failed: {e}", net.seed, s.name)
            });
        assert!(
            r.duration <= r.sequential_duration,
            "seed {} stage {}: makespan above sequential",
            net.seed,
            s.name
        );
        assert!(
            r.duration >= r.dma_busy.max(r.compute_busy),
            "seed {} stage {}: makespan below the resource floor",
            net.seed,
            s.name
        );
        total += r.duration;
        let mut o = Json::obj();
        o.set("name", s.name.as_str())
            .set("makespan", r.duration)
            .set("sequential_duration", r.sequential_duration)
            .set("dma_busy", r.dma_busy)
            .set("compute_busy", r.compute_busy);
        per_stage.push(o);
    }
    let mut o = Json::obj();
    o.set("total_makespan", total).set("per_stage", Json::Arr(per_stage));
    o
}

/// The §3.10 multi-resource expectations (v4): every stage replayed
/// double-buffered on the 2× memory "roomy" variant with the network's
/// sampled resource shape (k × m) and image batch — makespans, batched
/// sequential sums and per-resource busy vectors, all of which the Python
/// oracle's independent k × m list scheduler must reproduce bit-exactly.
fn multi_expectations(net: &FuzzNetwork) -> Json {
    let (k, m) = (net.dma_channels, net.compute_units);
    let mut per_stage: Vec<Json> = Vec::new();
    let mut total = 0u64;
    for s in &net.stages {
        let acc = Accelerator {
            size_mem: s.accelerator.size_mem * 2,
            ..s.accelerator
        }
        .with_overlap(OverlapMode::DoubleBuffered)
        .with_channels(k, m);
        let r = Simulator::new(s.layer, Platform::new(acc))
            .with_batch(net.batch)
            .run(&s.strategy)
            .unwrap_or_else(|e| {
                panic!("seed {} stage {}: multi-resource sim failed: {e}", net.seed, s.name)
            });
        assert!(
            r.duration <= r.sequential_duration,
            "seed {} stage {}: {k}x{m} makespan above the batched sequential sum",
            net.seed,
            s.name
        );
        assert!(
            r.duration
                >= r.dma_busy
                    .div_ceil(k as u64)
                    .max(r.compute_busy.div_ceil(m as u64)),
            "seed {} stage {}: {k}x{m} makespan below the resource floor",
            net.seed,
            s.name
        );
        total += r.duration;
        let mut o = Json::obj();
        o.set("name", s.name.as_str())
            .set("makespan", r.duration)
            .set("sequential_duration", r.sequential_duration)
            .set("dma_busy", r.dma_busy)
            .set("compute_busy", r.compute_busy)
            .set(
                "dma_busy_per",
                Json::Arr(r.dma_busy_per.iter().map(|&v| v.into()).collect()),
            )
            .set(
                "compute_busy_per",
                Json::Arr(r.compute_busy_per.iter().map(|&v| v.into()).collect()),
            );
        per_stage.push(o);
    }
    let mut o = Json::obj();
    o.set("dma_channels", k)
        .set("compute_units", m)
        .set("batch", net.batch)
        .set("total_makespan", total)
        .set("per_stage", Json::Arr(per_stage));
    o
}

/// The per-case fault model: every axis live (retries, both jitters,
/// shrink), seeded per network so the 24 cases pin 24 distinct streams.
fn case_fault_model(net_seed: u64) -> FaultModel {
    FaultModel {
        dma_fail_rate: 0.35,
        max_retries: 3,
        retry_penalty: 9,
        dma_jitter: 4,
        t_acc_jitter: 3,
        shrink_rate: 0.15,
        shrink_elements: 32,
        ..FaultModel::none()
    }
    .with_seed(1_000 + net_seed)
}

/// JSON form of a fault model — field names match the `[faults]` TOML keys,
/// which is also what the Python oracle's `FaultModel.from_json` reads.
fn fault_model_to_json(m: &FaultModel) -> Json {
    let mut o = Json::obj();
    o.set("seed", m.seed)
        .set("dma_fail_rate", m.dma_fail_rate)
        .set("max_retries", m.max_retries as u64)
        .set("retry_penalty", m.retry_penalty)
        .set("dma_jitter", m.dma_jitter)
        .set("t_acc_jitter", m.t_acc_jitter)
        .set("shrink_rate", m.shrink_rate)
        .set("shrink_elements", m.shrink_elements);
    o
}

/// Fault-injected expectations: the whole network replayed under `model`
/// in sequential mode, plus every stage replayed double-buffered on its own
/// accelerator — durations, retry / shrink counts and the analytic WCET
/// bound, all of which the Python oracle must reproduce bit-exactly from
/// the seeded stream alone. Since v4, stage `i` draws from
/// `model.for_stage(i)` on both codepaths (the pipeline runner does the
/// same mixing internally), so stages no longer share step-aligned draws.
fn faulted_expectations(net: &FuzzNetwork, model: &FaultModel) -> Json {
    let seq = net
        .to_network()
        .run_with_faults(Some(model))
        .unwrap_or_else(|e| {
            panic!("seed {}: faulted sequential sim failed: {e}", net.seed)
        });
    let seq_stages: Vec<Json> = seq
        .per_stage
        .iter()
        .map(|sr| {
            let mut o = Json::obj();
            o.set("name", sr.name.as_str())
                .set("duration", sr.duration)
                .set("fault_retries", sr.fault_retries)
                .set("mem_shrink_events", sr.mem_shrink_events)
                .set("wcet_bound", sr.wcet_bound.expect("active model"));
            o
        })
        .collect();

    let mut ovl_stages: Vec<Json> = Vec::new();
    let mut ovl_total = 0u64;
    for (i, s) in net.stages.iter().enumerate() {
        let acc = s.accelerator.with_overlap(OverlapMode::DoubleBuffered);
        let r = Simulator::new(s.layer, Platform::new(acc))
            .with_faults(model.for_stage(i))
            .run(&s.strategy)
            .unwrap_or_else(|e| {
                panic!("seed {} stage {}: faulted overlapped sim failed: {e}", net.seed, s.name)
            });
        assert!(
            r.duration <= r.sequential_duration,
            "seed {} stage {}: faulted makespan above the faulted sum",
            net.seed,
            s.name
        );
        assert!(r.wcet_bound.unwrap() >= r.duration);
        ovl_total += r.duration;
        let mut o = Json::obj();
        o.set("name", s.name.as_str())
            .set("makespan", r.duration)
            .set("sequential_duration", r.sequential_duration)
            .set("fault_retries", r.fault_retries)
            .set("mem_shrink_events", r.mem_shrink_events)
            .set("wcet_bound", r.wcet_bound.unwrap());
        ovl_stages.push(o);
    }

    let mut sequential = Json::obj();
    sequential
        .set("total_duration", seq.total_duration)
        .set("fault_retries", seq.fault_retries)
        .set("mem_shrink_events", seq.mem_shrink_events)
        .set("wcet_bound", seq.wcet_bound.expect("active model"))
        .set("per_stage", Json::Arr(seq_stages));
    let mut overlapped = Json::obj();
    overlapped
        .set("total_makespan", ovl_total)
        .set("per_stage", Json::Arr(ovl_stages));
    let mut o = Json::obj();
    o.set("model", fault_model_to_json(model))
        .set("sequential", sequential)
        .set("overlapped", overlapped);
    o
}

#[test]
fn emit_differential_cases() {
    let mut cases: Vec<Json> = Vec::new();
    let (mut st, mut di, mut gr, mut po) = (false, false, false, false);

    for seed in SEEDS {
        let net = random_network(seed);
        let (s, d, g, p) = net.features();
        st |= s;
        di |= d;
        gr |= g;
        po |= p;

        let report = net
            .to_network()
            .run()
            .unwrap_or_else(|e| panic!("seed {seed}: simulation failed: {e}"));

        let mut case = network_to_json(&net);
        let per_stage: Vec<Json> = report
            .per_stage
            .iter()
            .map(|sr| {
                assert!(
                    sr.comm_lower_bound <= sr.loaded_elements,
                    "seed {seed} stage {}: floor above the simulated loads",
                    sr.name
                );
                let mut o = Json::obj();
                o.set("name", sr.name.as_str())
                    .set("duration", sr.duration)
                    .set("loaded_elements", sr.loaded_elements)
                    .set("n_steps", sr.n_steps)
                    .set("comm_lower_bound", sr.comm_lower_bound)
                    .set("optimality_gap", sr.optimality_gap);
                o
            })
            .collect();
        let mut expected = Json::obj();
        expected
            .set("total_duration", report.total_duration)
            .set("per_stage", Json::Arr(per_stage))
            .set("overlapped", overlapped_expectations(&net, 1))
            .set("overlapped_roomy", overlapped_expectations(&net, 2))
            .set("multi", multi_expectations(&net))
            .set("faulted", faulted_expectations(&net, &case_fault_model(seed)));
        case.set("expected", expected);
        cases.push(case);
    }

    // The acceptance bar: the emitted set must cover every feature axis.
    assert!(st, "differential set has no strided case");
    assert!(di, "differential set has no dilated case");
    assert!(gr, "differential set has no grouped case");
    assert!(po, "differential set has no pooled case");
    assert!(cases.len() >= 20, "need ≥ 20 cases, got {}", cases.len());

    let mut doc = Json::obj();
    // v5: v4 plus per-stage certification expectations — the element-domain
    // communication floor (`comm_lower_bound`) and `optimality_gap`, both
    // replayed bit-exactly by the Python oracle's independent bound.
    doc.set("version", 5u64)
        .set("generator", "config::fuzz::random_network")
        .set("cases", Json::Arr(cases));

    let dir = target_dir();
    std::fs::create_dir_all(&dir).expect("create target dir");
    let path = dir.join("differential_cases.json");
    std::fs::write(&path, doc.to_string_pretty())
        .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
    eprintln!("wrote {} ({} cases)", path.display(), SEEDS.count());
}

/// The interchange must be loss-free: parse the emitted file back and check
/// a couple of invariants so a silent serialization regression cannot ship
/// a file pytest would mis-read.
#[test]
fn emitted_file_roundtrips() {
    // Generate independently of the writer test (tests run in any order).
    let net = random_network(7);
    let j = network_to_json(&net);
    let parsed = convoffload::util::json::parse(&j.to_string_pretty()).unwrap();
    let stages = parsed.get("stages").and_then(Json::as_arr).unwrap();
    assert_eq!(stages.len(), net.stages.len());
    for (js, s) in stages.iter().zip(&net.stages) {
        let layer = js.get("layer").unwrap();
        for (key, want) in [
            ("c_in", s.layer.c_in),
            ("h_in", s.layer.h_in),
            ("w_in", s.layer.w_in),
            ("h_k", s.layer.h_k),
            ("w_k", s.layer.w_k),
            ("n_kernels", s.layer.n_kernels),
            ("s_h", s.layer.s_h),
            ("s_w", s.layer.s_w),
            ("d_h", s.layer.d_h),
            ("d_w", s.layer.d_w),
            ("groups", s.layer.groups),
        ] {
            assert_eq!(
                layer.get(key).and_then(Json::as_usize),
                Some(want),
                "{key} of stage {}",
                s.name
            );
        }
        let groups = js.get("strategy_groups").and_then(Json::as_arr).unwrap();
        let flat: Vec<u32> = groups
            .iter()
            .flat_map(|g| g.as_arr().unwrap().iter())
            .map(|v| v.as_u64().unwrap() as u32)
            .collect();
        let mut sorted = flat.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, s.layer.all_patches().collect::<Vec<_>>());
    }
}

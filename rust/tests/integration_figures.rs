//! Integration tests over the figure harness: the qualitative claims of the
//! paper's evaluation must hold on (reduced) grids, and the emitted CSV must
//! be machine-readable.

use convoffload::bench_harness as bh;
use convoffload::config::layer_preset;
use convoffload::util::csv;

/// Fig. 11 on the real LeNet-5 conv1 layer, full group range.
#[test]
fn fig11_full_claims() {
    let layer = layer_preset("lenet5-conv1").unwrap().layer;
    let w_out = layer.w_out();
    let sizes: Vec<usize> = (1..=w_out + 4).collect();
    let rows = bh::fig11(&layer, &sizes);

    // claim 1: zigzag wins in the small-group regime
    let small_wins = rows
        .iter()
        .filter(|r| r.group_size < w_out / 2)
        .filter(|r| r.zigzag < r.row_by_row)
        .count();
    assert!(small_wins > 5, "zigzag should win most small-group points");

    // claim 2: crossover exists — row-by-row wins somewhere later
    assert!(
        rows.iter().any(|r| r.row_by_row < r.zigzag),
        "row-by-row should win somewhere after the crossover"
    );

    // claim 3: equality at multiples of W_out
    for r in &rows {
        if r.group_size % w_out == 0 {
            assert_eq!(r.zigzag, r.row_by_row, "g={}", r.group_size);
        }
    }
}

/// The §7.2 claim that the curve shapes repeat on other layers: check the
/// multiples-of-`W_out` equality and the small-group ZigZag advantage on a
/// ResNet-8 style layer and on LeNet-5 conv2.
#[test]
fn fig11_shape_generalizes_to_other_layers() {
    for preset in ["lenet5-conv2", "resnet8-conv2"] {
        let layer = layer_preset(preset).unwrap().layer;
        let w_out = layer.w_out();
        let sizes: Vec<usize> = (1..=w_out * 2).collect();
        let rows = bh::fig11(&layer, &sizes);
        assert!(
            rows.iter()
                .take(w_out / 2)
                .any(|r| r.zigzag < r.row_by_row),
            "{preset}: zigzag should win small groups"
        );
        for r in &rows {
            if r.group_size % w_out == 0 {
                assert_eq!(r.zigzag, r.row_by_row, "{preset} g={}", r.group_size);
            }
        }
    }
}

/// Fig. 12 (reduced grid): OPL ≤ min(heuristics) < S1-baseline everywhere.
#[test]
fn fig12_ordering_claims() {
    let rows = bh::fig12(&[4, 5, 6, 8], 4, 11);
    for r in &rows {
        let best_heur = r.row_by_row.min(r.zigzag);
        assert!(r.opl <= best_heur, "{r:?}");
        assert!(r.s1_baseline > best_heur, "{r:?}");
    }
    let text = bh::fig12::to_csv(&rows);
    let parsed = csv::parse(&text).unwrap();
    assert_eq!(parsed.len(), rows.len() + 1);
    // numeric columns parse back
    for row in &parsed[1..] {
        for field in row {
            field.parse::<u64>().unwrap();
        }
    }
}

/// Fig. 13 (reduced grid): the two regions + CSV integrity.
#[test]
fn fig13_region_claims() {
    let inputs = [4usize, 8, 10];
    let groups = [2usize, 6, 10];
    let cells = bh::fig13(&inputs, &groups, 11);
    assert_eq!(cells.len(), 9);

    // all gains are non-negative and bounded by 100%
    for c in &cells {
        assert!((0.0..=100.0).contains(&c.gain_pct), "{c:?}");
    }
    // upper-right corner: 4x4 input (4 patches), group 10 → single group
    let ur = cells.iter().find(|c| c.h_in == 4 && c.group == 10).unwrap();
    assert_eq!(ur.gain_pct, 0.0);
    // lower-left corner: 10x10 input, group 2 → sizable gain (paper: ≤30%)
    let ll = cells.iter().find(|c| c.h_in == 10 && c.group == 2).unwrap();
    assert!(ll.gain_pct > 3.0, "lower-left gain too small: {ll:?}");

    // ascii heatmap covers the grid
    let ascii = bh::fig13::to_ascii(&inputs, &groups, &cells);
    for h in &inputs {
        assert!(ascii.contains(&format!("{h:>6} |")));
    }
}

/// Output files land where the CLI promises.
#[test]
fn write_outputs_creates_files() {
    let dir = std::env::temp_dir().join("convoffload_fig_test");
    let _ = std::fs::remove_dir_all(&dir);
    bh::write_outputs(&dir, "fig11", "a,b\n1,2\n", "chart\n").unwrap();
    assert!(dir.join("fig11.csv").exists());
    assert!(dir.join("fig11.txt").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

//! Integration tests for deterministic fault injection: zero-fault
//! bit-identity against every clean baseline, fault-stream determinism
//! across seeds / thread counts / overlap modes, and the analytic k-fault
//! WCET bound holding over hundreds of simulated traces.

use convoffload::config::fuzz::random_network;
use convoffload::config::network_preset;
use convoffload::planner::{AcceleratorSpec, BatchPlanner, PlanOptions};
use convoffload::platform::{Accelerator, FaultModel, OverlapMode, Platform};
use convoffload::sim::Simulator;

/// The differential harness's seed range — reused so the fault properties
/// cover the same stride/dilation/groups/pooling feature axes.
const SEEDS: std::ops::RangeInclusive<u64> = 1..=24;

fn quick_options() -> PlanOptions {
    PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(4),
        seed: 2026,
        anneal_iters: 1_500,
        anneal_starts: 2,
        threads: 0,
        overlap: OverlapMode::Sequential,
        dma_channels: 1,
        compute_units: 1,
    }
}

/// A live model exercising every fault axis at once.
fn storm(seed: u64) -> FaultModel {
    FaultModel {
        dma_fail_rate: 0.35,
        max_retries: 3,
        retry_penalty: 9,
        dma_jitter: 4,
        t_acc_jitter: 3,
        shrink_rate: 0.15,
        shrink_elements: 32,
        ..FaultModel::none()
    }
    .with_seed(seed)
}

/// Zero-fault bit-identity: attaching an *inactive* model (any seed) to
/// every fuzz network, under both duration semantics, reproduces the clean
/// run bit-exactly and reports no fault fields at all.
#[test]
fn inert_fault_model_is_bit_identical_to_clean_runs() {
    for seed in SEEDS {
        let net = random_network(seed).to_network();
        let clean = net.run().unwrap();
        let inert = net
            .run_with_faults(Some(&FaultModel::none().with_seed(seed)))
            .unwrap();
        assert_eq!(inert.total_duration, clean.total_duration, "seed {seed}");
        assert_eq!(inert.fault_retries, 0);
        assert_eq!(inert.mem_shrink_events, 0);
        assert_eq!(inert.wcet_bound, None, "inactive model reports no bound");
        for (a, b) in inert.per_stage.iter().zip(&clean.per_stage) {
            assert_eq!(a.duration, b.duration, "seed {seed} stage {}", a.name);
            assert_eq!(a.loaded_elements, b.loaded_elements);
            assert_eq!(a.n_steps, b.n_steps);
        }

        // Same identity under the double-buffered timeline, per stage.
        for s in &random_network(seed).stages {
            let acc = s.accelerator.with_overlap(OverlapMode::DoubleBuffered);
            let clean = Simulator::new(s.layer, Platform::new(acc))
                .run(&s.strategy)
                .unwrap();
            let inert = Simulator::new(s.layer, Platform::new(acc))
                .with_faults(FaultModel::none().with_seed(seed ^ 0xABCD))
                .run(&s.strategy)
                .unwrap();
            assert_eq!(inert.duration, clean.duration, "seed {seed} {}", s.name);
            assert_eq!(inert.dma_busy, clean.dma_busy);
            assert_eq!(inert.compute_busy, clean.compute_busy);
            assert_eq!(inert.wcet_bound, None);
        }
    }
}

/// Zero-fault planning identity: a batch planner carrying an inert fault
/// model reproduces the pinned sequential and double-buffered baselines
/// bit-exactly (same durations, strategies and counters as no model at all).
#[test]
fn inert_fault_model_reproduces_the_pinned_planner_baselines() {
    let nets = vec![
        network_preset("lenet5").unwrap(),
        network_preset("resnet8").unwrap(),
        network_preset("mobilenet_slim").unwrap(),
    ];
    for (overlap, totals) in [
        (OverlapMode::Sequential, [7100u64, 27644, 3568]),
        (OverlapMode::DoubleBuffered, [6883, 27272, 3554]),
    ] {
        let mut opts = quick_options();
        opts.overlap = overlap;
        let clean = BatchPlanner::new(opts.clone()).plan_batch(&nets).unwrap();
        let inert = BatchPlanner::new(opts)
            .with_faults(FaultModel::none().with_seed(7))
            .plan_batch(&nets)
            .unwrap();
        assert_eq!(inert.stats, clean.stats, "{overlap:?}");
        for ((a, b), &pin) in clean.plans.iter().zip(&inert.plans).zip(&totals) {
            assert_eq!(a.total_duration, b.total_duration, "{overlap:?}");
            assert!(b.total_duration <= pin, "{}: above pinned {pin}", b.network);
            for (la, lb) in a.layers.iter().zip(&b.layers) {
                assert_eq!(la.strategy, lb.strategy);
                assert_eq!(la.winner, lb.winner);
                assert_eq!(la.duration, lb.duration);
            }
        }
    }
}

/// Fault-seed determinism: the same (model, seed) yields the same report
/// however often it runs, different seeds genuinely vary the trace, and the
/// retry stream is a function of the step shapes alone — so Sequential and
/// DoubleBuffered runs of one strategy draw identical retries and shrinks.
#[test]
fn fault_streams_are_deterministic_and_mode_agnostic() {
    let mut seeds_varied = false;
    for seed in SEEDS {
        let net = random_network(seed).to_network();
        let m = storm(1000 + seed);
        let a = net.run_with_faults(Some(&m)).unwrap();
        let b = net.run_with_faults(Some(&m)).unwrap();
        assert_eq!(a.total_duration, b.total_duration, "seed {seed}");
        assert_eq!(a.fault_retries, b.fault_retries);
        assert_eq!(a.mem_shrink_events, b.mem_shrink_events);
        assert_eq!(a.wcet_bound, b.wcet_bound);
        let other = net.run_with_faults(Some(&m.with_seed(9_999))).unwrap();
        seeds_varied |= other.total_duration != a.total_duration;

        for s in &random_network(seed).stages {
            let seq = Simulator::new(s.layer, Platform::new(s.accelerator))
                .with_faults(m)
                .run(&s.strategy)
                .unwrap();
            let db = Simulator::new(
                s.layer,
                Platform::new(s.accelerator.with_overlap(OverlapMode::DoubleBuffered)),
            )
            .with_faults(m)
            .run(&s.strategy)
            .unwrap();
            assert_eq!(seq.fault_retries, db.fault_retries, "seed {seed} {}", s.name);
            assert_eq!(seq.mem_shrink_events, db.mem_shrink_events);
            assert!(db.duration <= seq.duration, "timeline beats the faulted sum");
            assert!(db.duration >= db.dma_busy.max(db.compute_busy));
        }
    }
    assert!(seeds_varied, "distinct fault seeds never changed any trace");
}

/// A fault-injected *batch* is deterministic across worker-pool sizes: the
/// race pool changes scheduling, never the per-network faulted durations or
/// the degraded-stage accounting.
#[test]
fn faulted_batch_is_deterministic_across_thread_counts() {
    let nets = vec![
        network_preset("lenet5").unwrap(),
        network_preset("resnet8").unwrap(),
    ];
    let m = FaultModel {
        dma_fail_rate: 0.4,
        max_retries: 3,
        retry_penalty: 6,
        dma_jitter: 2,
        ..FaultModel::none()
    }
    .with_seed(13);
    let mut opts = quick_options();
    let base = BatchPlanner::new(opts.clone())
        .with_faults(m)
        .plan_batch(&nets)
        .unwrap();
    for threads in [1usize, 2, 8] {
        opts.threads = threads;
        let again = BatchPlanner::new(opts.clone())
            .with_faults(m)
            .plan_batch(&nets)
            .unwrap();
        assert_eq!(again.stats, base.stats, "threads={threads}");
        for (a, b) in base.plans.iter().zip(&again.plans) {
            assert_eq!(a.total_duration, b.total_duration, "threads={threads}");
            for (la, lb) in a.layers.iter().zip(&b.layers) {
                assert_eq!(la.strategy, lb.strategy, "threads={threads}");
                assert_eq!(la.duration, lb.duration, "threads={threads}");
            }
        }
    }
}

/// The analytic bound: monotone in `k`, and it dominates every one of the
/// hundreds of simulated traces produced by sweeping fault seeds over the
/// fuzz networks — per stage and summed at the network level.
#[test]
fn wcet_bound_is_monotone_and_dominates_every_simulated_trace() {
    // Monotonicity, directly on the closed form.
    let m = storm(0);
    let mut prev = 0;
    for k in 0..64u64 {
        let w = m.makespan_under_k_faults(10_000, 50, 40, 120, k);
        assert!(w >= prev, "WCET bound must be monotone in k");
        prev = w;
    }

    // Dominance over simulated traces: 24 networks x 10 fault seeds, both
    // overlap modes = several hundred independent traces.
    let mut traces = 0u32;
    for seed in SEEDS {
        let fuzz = random_network(seed);
        let net = fuzz.to_network();
        for fault_seed in 0..10u64 {
            let m = storm(seed.wrapping_mul(31) ^ fault_seed);
            let r = net.run_with_faults(Some(&m)).unwrap();
            let wcet = r.wcet_bound.expect("active model must report a bound");
            assert!(
                wcet >= r.total_duration,
                "seed {seed}/{fault_seed}: network WCET {wcet} < {}",
                r.total_duration
            );
            for s in &r.per_stage {
                assert!(
                    s.wcet_bound.unwrap() >= s.duration,
                    "seed {seed}/{fault_seed} stage {}",
                    s.name
                );
                traces += 1;
            }
            for s in &fuzz.stages {
                let db = Simulator::new(
                    s.layer,
                    Platform::new(
                        s.accelerator.with_overlap(OverlapMode::DoubleBuffered),
                    ),
                )
                .with_faults(m)
                .run(&s.strategy)
                .unwrap();
                assert!(
                    db.wcet_bound.unwrap() >= db.duration,
                    "seed {seed}/{fault_seed} stage {} (overlapped)",
                    s.name
                );
                traces += 1;
            }
        }
    }
    assert!(traces >= 400, "expected hundreds of traces, got {traces}");
}

/// Memory-shrink faults serialize prefetches but never touch functional
/// semantics: a shrink-heavy model leaves the sequential duration equal to
/// the jitter-free sum and only stretches the overlapped makespan.
#[test]
fn shrink_storms_degrade_only_the_overlapped_makespan() {
    let m = FaultModel {
        shrink_rate: 1.0,
        shrink_elements: 64,
        ..FaultModel::none()
    }
    .with_seed(3);
    let mut stretched = 0u32;
    for seed in SEEDS {
        for s in &random_network(seed).stages {
            let clean_seq = Simulator::new(s.layer, Platform::new(s.accelerator))
                .run(&s.strategy)
                .unwrap();
            let fault_seq = Simulator::new(s.layer, Platform::new(s.accelerator))
                .with_faults(m)
                .run(&s.strategy)
                .unwrap();
            // No retries, no jitter: the Definition-3 sum is untouched.
            assert_eq!(fault_seq.duration, clean_seq.duration, "seed {seed}");
            assert!(fault_seq.mem_shrink_events > 0, "rate-1.0 must fire");

            let db_acc = s.accelerator.with_overlap(OverlapMode::DoubleBuffered);
            let clean_db = Simulator::new(s.layer, Platform::new(db_acc))
                .run(&s.strategy)
                .unwrap();
            let fault_db = Simulator::new(s.layer, Platform::new(db_acc))
                .with_faults(m)
                .run(&s.strategy)
                .unwrap();
            assert!(fault_db.duration >= clean_db.duration, "seed {seed}");
            assert!(fault_db.duration <= fault_seq.duration);
            stretched += u32::from(fault_db.duration > clean_db.duration);
        }
    }
    assert!(stretched > 0, "shrink storm never forced a serialization");
}

//! Reproduction of the paper's Example 2 (§4.2, Fig. 9), set by set.
//!
//! Layer: `I ∈ R^{2×5×5}`, `Λ = {K⁰, K¹}` with 3×3 kernels, strides 1.
//! Group size 2 (the paper's stated `nb_patches_max_S1`). Both strategies
//! write each output back at the next step.
//!
//! Spatial pixel ids are `h·W_in + w`; the paper lists *elements*
//! `I_{c,h,w}` — each spatial pixel stands for `C_in = 2` of them.

use convoffload::conv::ConvLayer;
use convoffload::platform::{Accelerator, Platform};
use convoffload::sim::Simulator;
use convoffload::strategy::{row_by_row, zigzag};

fn layer() -> ConvLayer {
    ConvLayer::new(2, 5, 5, 3, 3, 2, 1, 1).unwrap()
}

fn px(h: usize, w: usize) -> u32 {
    (h * 5 + w) as u32
}

#[test]
fn row_by_row_step2_sets() {
    let l = layer();
    let steps = row_by_row(&l, 2).compile(&l);
    let s2 = &steps[1];

    // F_2^inp_Row = {I_{·,0,0}, I_{·,0,1}} → spatial pixels (0,0), (0,1)
    assert_eq!(s2.free_inp.to_vec(), vec![px(0, 0), px(0, 1)]);

    // I_2^slice_Row = {I_{·,0,4}, I_{·,1,4}, I_{·,2,4}, I_{·,3,0}, I_{·,3,1}, I_{·,3,2}}
    let mut want = vec![px(0, 4), px(1, 4), px(2, 4), px(3, 0), px(3, 1), px(3, 2)];
    want.sort();
    assert_eq!(s2.load_inp.to_vec(), want);

    // F_2^ker = K_2^sub = ∅
    assert!(s2.free_ker.is_empty());
    assert!(s2.load_ker.is_empty());

    // W_2 = outputs of step 1's patches P(0,0), P(0,1)
    assert_eq!(s2.write.to_vec(), vec![0, 1]);

    // step 2 computes {P(0,2), P(1,0)} (row-major ids 2, 3 — Fig. 9 left)
    assert_eq!(s2.group, vec![2, 3]);
}

#[test]
fn zigzag_step2_sets() {
    let l = layer();
    let steps = zigzag(&l, 2).compile(&l);
    let s2 = &steps[1];

    // F_2^inp_ZigZag = {I_{·,0,0}, I_{·,0,1}, I_{·,1,0}, I_{·,1,1}, I_{·,2,0}, I_{·,2,1}}
    let mut want_free = vec![
        px(0, 0), px(0, 1), px(1, 0), px(1, 1), px(2, 0), px(2, 1),
    ];
    want_free.sort();
    assert_eq!(s2.free_inp.to_vec(), want_free);

    // I_2^slice_ZigZag = {I_{·,0,4}, I_{·,1,4}, I_{·,2,4}, I_{·,3,4}, I_{·,3,3}, I_{·,3,2}}
    let mut want_load = vec![
        px(0, 4), px(1, 4), px(2, 4), px(3, 4), px(3, 3), px(3, 2),
    ];
    want_load.sort();
    assert_eq!(s2.load_inp.to_vec(), want_load);

    assert!(s2.free_ker.is_empty());
    assert!(s2.load_ker.is_empty());
    assert_eq!(s2.write.to_vec(), vec![0, 1]);

    // step 2 computes {P(0,2), P(1,2)} (zigzag: row 1 runs right→left)
    assert_eq!(s2.group, vec![2, 5]);
}

#[test]
fn step2_memory_footprints_match_paper() {
    // M_2^inp_Row = 32 elements, M_2^inp_ZigZag = 24 elements.
    let l = layer();
    let acc = Accelerator::for_group_size(&l, 2);
    let sim = Simulator::new(l, Platform::new(acc));
    let row = sim.run(&row_by_row(&l, 2)).unwrap();
    let zig = sim.run(&zigzag(&l, 2)).unwrap();
    assert_eq!(row.steps[1].resident_input_elements, 32);
    assert_eq!(zig.steps[1].resident_input_elements, 24);
}

#[test]
fn step2_durations_equal_across_strategies() {
    // The paper's point: δ(s_2) is identical for both strategies — loads 6
    // spatial pixels (= 12 elements) and writes 2 patches (= 4 elements)
    // either way; only the *footprint* differs.
    //
    // The paper's example counts δ(s_2) = 6·t_l + 2·t_w + t_acc in spatial
    // pixels / patches; in elements (×C_in = ×2 for loads, ×C_out = ×2 for
    // writes) that is 12·t_l + 4·t_w + t_acc. We assert the element form
    // and the equality, which is the claim being made.
    let l = layer();
    let mut acc = Accelerator::for_group_size(&l, 2);
    acc.t_w = 1;
    let sim = Simulator::new(l, Platform::new(acc));
    let row = sim.run(&row_by_row(&l, 2)).unwrap();
    let zig = sim.run(&zigzag(&l, 2)).unwrap();
    for r in [&row, &zig] {
        assert_eq!(r.steps[1].cost.loaded_elements, 12);
        assert_eq!(r.steps[1].cost.written_elements, 4);
        assert_eq!(r.steps[1].duration, 12 + 4 + 1);
    }
    assert_eq!(row.steps[1].duration, zig.steps[1].duration);
}

#[test]
fn both_strategies_need_five_steps() {
    // |X| = 9 patches, groups of 2 → K_min = ⌈9/2⌉ = 5 compute steps.
    let l = layer();
    let acc = Accelerator::for_group_size(&l, 2);
    assert_eq!(acc.k_min(&l), 5);
    assert_eq!(row_by_row(&l, 2).n_steps(), 5);
    assert_eq!(zigzag(&l, 2).n_steps(), 5);
}

#[test]
fn first_step_loads_all_kernels() {
    // Definition 12/16: K_1^sub = Λ, K_i^sub = ∅ for i > 1; kernels stay
    // resident until the terminal flush (F_n^ker = Λ).
    let l = layer();
    for s in [row_by_row(&l, 2), zigzag(&l, 2)] {
        let steps = s.compile(&l);
        assert_eq!(steps[0].load_ker.len(), 2);
        for st in &steps[1..] {
            assert!(st.load_ker.is_empty());
        }
        assert_eq!(steps.last().unwrap().free_ker.len(), 2);
    }
}

#[test]
fn functional_equivalence_of_both_strategies() {
    // Same convolution result regardless of the step order (the output
    // independence property the paper derives from the conv equation).
    let l = layer();
    let acc = Accelerator::for_group_size(&l, 2);
    let sim = Simulator::new(l, Platform::new(acc));
    let input = convoffload::conv::reference::synth_tensor(l.input_dims().len(), 5);
    let kernels = convoffload::conv::reference::synth_tensor(l.kernel_elements(), 6);
    let mut backend = convoffload::sim::RustOracleBackend;
    let row = sim
        .run_functional(&row_by_row(&l, 2), &input, &kernels, &mut backend)
        .unwrap();
    let zig = sim
        .run_functional(&zigzag(&l, 2), &input, &kernels, &mut backend)
        .unwrap();
    assert_eq!(row.output, zig.output);
    assert_eq!(row.functional_ok(1e-5), Some(true));
    assert_eq!(zig.functional_ok(1e-5), Some(true));
}

//! Integration tests for the hardened plan-server: protocol errors,
//! admission control, deadlines, crash-safe warm restart, determinism.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

use convoffload::config::network_preset;
use convoffload::planner::{batch_to_json, AcceleratorSpec, BatchPlanner, PlanOptions};
use convoffload::server::{Handle, PlanServer, ServerConfig};
use convoffload::util::json;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "convoffload-server-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn quick_options(threads: usize) -> PlanOptions {
    PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(4),
        seed: 2026,
        anneal_iters: 1_500,
        anneal_starts: 2,
        threads,
        ..PlanOptions::default()
    }
}

fn start(state_dir: &Path, queue_capacity: usize, threads: usize) -> Handle {
    PlanServer::start(ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_capacity,
        max_request_bytes: 16 * 1024,
        read_timeout_ms: 30_000,
        state_dir: state_dir.to_path_buf(),
        shards: 4,
        options: quick_options(0),
    })
    .expect("server starts")
}

/// One client connection: send a line, read the reply line.
struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { writer: stream, reader }
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("read response");
        line.trim_end().to_string()
    }

    fn roundtrip(&mut self, line: &str) -> json::Json {
        self.send(line);
        json::parse(&self.recv()).expect("response is JSON")
    }
}

fn error_kind(resp: &json::Json) -> &str {
    assert_eq!(resp.get("ok").and_then(json::Json::as_bool), Some(false));
    resp.get("error")
        .and_then(|e| e.get("kind"))
        .and_then(json::Json::as_str)
        .expect("error kind")
}

#[test]
fn malformed_requests_get_structured_errors_and_the_connection_survives() {
    let dir = tmp_dir("malformed");
    let server = start(&dir, 4, 0);
    let mut c = Client::connect(server.local_addr);
    // The malformed-input regression set, server side — the same shapes the
    // CLI rejects with exit code 2 (see `cli_and_server_reject_the_same_inputs`).
    for bad in [
        "this is not json",
        r#"{"op":"conquer"}"#,
        r#"{"op":"plan","networks":[]}"#,
        r#"{"op":"plan","networks":["vgg99"]}"#,
        r#"{"op":"simulate","layer":"nope"}"#,
        r#"{"op":"simulate","layer":"example1","strategy":"../../etc/passwd"}"#,
        r#"{"op":"simulate","layer":"example1","group":0}"#,
    ] {
        let resp = c.roundtrip(bad);
        assert_eq!(error_kind(&resp), "malformed", "{bad}");
    }
    // after seven rejections the same connection still serves
    let health = c.roundtrip(r#"{"op":"health"}"#);
    assert_eq!(health.get("ok").and_then(json::Json::as_bool), Some(true));
    assert_eq!(health.get("alive").and_then(json::Json::as_bool), Some(true));

    let stats = c.roundtrip(r#"{"op":"stats"}"#);
    let malformed = stats
        .get("stats")
        .and_then(|s| s.get("rejected_malformed"))
        .and_then(json::Json::as_u64);
    assert_eq!(malformed, Some(7));

    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn oversized_request_is_rejected_without_buffering_it() {
    let dir = tmp_dir("oversized");
    let server = start(&dir, 4, 0);
    let mut c = Client::connect(server.local_addr);
    // 20 KiB of JSON against a 16 KiB bound (small enough to sit in the
    // socket buffers, so the client's write cannot block on a dead reader)
    let huge = format!(
        r#"{{"op":"plan","networks":["{}"]}}"#,
        "x".repeat(20 * 1024)
    );
    let resp = c.roundtrip(&huge);
    assert_eq!(error_kind(&resp), "too-large");
    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn full_queue_sheds_with_an_explicit_overloaded_error() {
    let dir = tmp_dir("overload");
    let server = start(&dir, 1, 0);
    // Hold the worker so nothing drains: admission is all that acts.
    server.pause();
    let mut first = Client::connect(server.local_addr);
    first.send(r#"{"op":"plan","networks":["lenet5"]}"#);
    // wait until the first request occupies the queue's only slot
    let mut probe = Client::connect(server.local_addr);
    let mut admitted = false;
    for _ in 0..100 {
        let h = probe.roundtrip(r#"{"op":"health"}"#);
        if h.get("queue_depth").and_then(json::Json::as_u64) == Some(1) {
            admitted = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(admitted, "first request must reach the queue");
    // the second plan finds the queue full -> overloaded, immediately
    let resp = probe.roundtrip(r#"{"op":"plan","networks":["lenet5"]}"#);
    assert_eq!(error_kind(&resp), "overloaded");
    // releasing the worker serves the queued request normally
    server.resume();
    let ok = json::parse(&first.recv()).unwrap();
    assert_eq!(ok.get("ok").and_then(json::Json::as_bool), Some(true));
    assert!(ok.get("report").is_some());
    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn zero_pressure_plan_is_bit_identical_to_the_batch_planner() {
    let dir = tmp_dir("identity");
    let server = start(&dir, 4, 0);
    let mut c = Client::connect(server.local_addr);
    let resp = c.roundtrip(r#"{"op":"plan","networks":["lenet5","lenet5"]}"#);
    assert_eq!(resp.get("ok").and_then(json::Json::as_bool), Some(true));
    assert!(
        resp.get("degraded").is_none(),
        "idle queue + no deadline must not degrade"
    );
    // the same batch through the library, cold cache, same options
    let lenet = network_preset("lenet5").unwrap();
    let oracle = BatchPlanner::new(quick_options(0))
        .plan_batch(&[lenet.clone(), lenet])
        .unwrap();
    let served = resp.get("report").expect("report");
    let expect = batch_to_json(&oracle);
    assert_eq!(
        served.get("plans"),
        expect.get("plans"),
        "plans must be bit-identical to plan-batch"
    );
    // stats differ only in persistence fields (the server has a cache);
    // the planning outcome fields must agree exactly
    for field in ["networks", "stages_total", "unique_problems", "dedup_hits", "anneal_iters_run"] {
        assert_eq!(
            served.get("stats").unwrap().get(field),
            expect.get("stats").unwrap().get(field),
            "{field}"
        );
    }
    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tight_deadline_returns_a_tagged_degraded_plan_that_still_validates() {
    let dir = tmp_dir("deadline");
    let server = start(&dir, 4, 0);
    let mut c = Client::connect(server.local_addr);
    // 50 ms budget -> heuristic rung by the ladder, regardless of timing
    let resp = c.roundtrip(r#"{"op":"plan","networks":["lenet5"],"deadline_ms":50}"#);
    assert_eq!(resp.get("ok").and_then(json::Json::as_bool), Some(true));
    let tag = resp.get("degraded").expect("tight deadline must tag degraded");
    assert_eq!(
        tag.get("rung").and_then(json::Json::as_str),
        Some("heuristic")
    );
    let cause = tag.get("cause").and_then(json::Json::as_str).unwrap();
    assert!(cause == "deadline" || cause == "load", "cause: {cause}");
    // the degraded plan is still a complete, simulable plan
    let report = resp.get("report").unwrap();
    let plans = report.get("plans").and_then(json::Json::as_arr).unwrap();
    assert_eq!(plans.len(), 1);
    for plan in plans {
        assert!(plan.get("total_duration").and_then(json::Json::as_u64).unwrap() > 0);
        // certification fields ride along on every served plan
        assert!(
            plan.get("total_comm_lower_bound")
                .and_then(json::Json::as_u64)
                .unwrap()
                > 0
        );
        assert!(plan.get("worst_optimality_gap").and_then(json::Json::as_f64).is_some());
        for layer in plan.get("layers").and_then(json::Json::as_arr).unwrap() {
            assert!(layer.get("n_steps").and_then(json::Json::as_u64).unwrap() > 0);
            let bound = layer.get("comm_lower_bound").and_then(json::Json::as_u64).unwrap();
            let loaded = layer.get("loaded_pixels").and_then(json::Json::as_u64).unwrap();
            assert!(bound > 0 && bound <= loaded, "floor must bound the winner");
        }
    }
    // heuristic rung ran zero annealing iterations
    assert_eq!(
        report
            .get("stats")
            .and_then(|s| s.get("anneal_iters_run"))
            .and_then(json::Json::as_u64),
        Some(0)
    );
    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn restart_replays_the_journal_and_serves_the_second_request_fully_cached() {
    let dir = tmp_dir("restart");
    // Fabricate a crash: a journal holding a recv with no matching done —
    // exactly what a kill between admission and completion leaves behind.
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("journal.jsonl"),
        r#"{"e":"recv","id":0,"req":{"networks":["lenet5"],"op":"plan"},"v":1}"#.to_string() + "\n",
    )
    .unwrap();

    let server = start(&dir, 4, 0);
    let mut c = Client::connect(server.local_addr);
    let stats = c.roundtrip(r#"{"op":"stats"}"#);
    assert_eq!(
        stats
            .get("stats")
            .and_then(|s| s.get("journal_replayed"))
            .and_then(json::Json::as_u64),
        Some(1),
        "the in-flight request must replay on startup"
    );
    // replay warmed the cache: the same request is now a pure cache serve
    let resp = c.roundtrip(r#"{"op":"plan","networks":["lenet5"]}"#);
    let report_stats = resp.get("report").unwrap().get("stats").unwrap();
    assert_eq!(
        report_stats.get("anneal_iters_run").and_then(json::Json::as_u64),
        Some(0),
        "warm restart: zero anneal iterations"
    );
    assert_eq!(
        report_stats.get("store_misses").and_then(json::Json::as_u64),
        Some(0)
    );
    server.shutdown();
    server.wait();
    // clean shutdown compacts the journal to empty
    let journal = std::fs::read_to_string(dir.join("journal.jsonl")).unwrap();
    assert!(journal.is_empty(), "journal after clean shutdown: {journal:?}");

    // and a plain restart on the clean state dir reopens the shards warm
    let again = start(&dir, 4, 0);
    let mut c2 = Client::connect(again.local_addr);
    let resp2 = c2.roundtrip(r#"{"op":"plan","networks":["lenet5"]}"#);
    assert_eq!(
        resp2
            .get("report")
            .and_then(|r| r.get("stats"))
            .and_then(|s| s.get("anneal_iters_run"))
            .and_then(json::Json::as_u64),
        Some(0)
    );
    again.shutdown();
    again.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn quarantined_journal_starts_cold_instead_of_replaying_garbage() {
    let dir = tmp_dir("quarantine");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("journal.jsonl"),
        "garbage line\n{\"e\":\"recv\",\"id\":1,\"req\":{\"networks\":[\"lenet5\"],\"op\":\"plan\"},\"v\":1}\n",
    )
    .unwrap();
    let server = start(&dir, 4, 0);
    assert!(
        dir.join("journal.quarantined").exists(),
        "corrupt journal must be set aside"
    );
    let mut c = Client::connect(server.local_addr);
    let stats = c.roundtrip(r#"{"op":"stats"}"#);
    assert_eq!(
        stats
            .get("stats")
            .and_then(|s| s.get("journal_replayed"))
            .and_then(json::Json::as_u64),
        Some(0)
    );
    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn simulate_op_answers_over_the_wire() {
    let dir = tmp_dir("simulate");
    let server = start(&dir, 4, 0);
    let mut c = Client::connect(server.local_addr);
    let resp = c.roundtrip(
        r#"{"op":"simulate","layer":"example1","strategy":"zigzag","group":2,"batch":1}"#,
    );
    assert_eq!(resp.get("ok").and_then(json::Json::as_bool), Some(true));
    assert!(resp.get("duration").and_then(json::Json::as_u64).unwrap() > 0);
    assert!(resp.get("n_steps").and_then(json::Json::as_u64).unwrap() > 0);
    server.shutdown();
    server.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Determinism under concurrency: two servers differing only in race thread
/// count produce byte-identical plan responses from equally-cold caches.
#[test]
fn plan_responses_are_identical_across_race_thread_counts() {
    let mut responses = Vec::new();
    for threads in [1usize, 8] {
        let dir = tmp_dir(&format!("threads{threads}"));
        let server = PlanServer::start(ServerConfig {
            addr: "127.0.0.1:0".into(),
            queue_capacity: 4,
            max_request_bytes: 16 * 1024,
            read_timeout_ms: 30_000,
            state_dir: dir.clone(),
            shards: 4,
            options: quick_options(threads),
        })
        .unwrap();
        let mut c = Client::connect(server.local_addr);
        c.send(r#"{"op":"plan","networks":["lenet5","resnet8"]}"#);
        responses.push(c.recv());
        server.shutdown();
        server.wait();
        let _ = std::fs::remove_dir_all(&dir);
    }
    assert_eq!(
        responses[0], responses[1],
        "thread count must not change a single byte of the response"
    );
}

/// The CLI and the server reject the same malformed inputs — the shared
/// validators (`config::from_toml`, `FaultModel::from_spec`, preset lookups)
/// fail loudly in both surfaces.
#[test]
fn cli_and_server_reject_the_same_inputs() {
    use convoffload::config::ExperimentConfig;
    use convoffload::platform::FaultModel;
    use convoffload::server::protocol::{parse_request, ErrorKind};

    // zero / negative dims in a TOML layer file fail loudly
    let bad_toml =
        "[layer]\nc_in = 0\nh_in = 8\nw_in = 8\nh_k = 3\nw_k = 3\nn = 1\n";
    let err = ExperimentConfig::from_toml(bad_toml).unwrap_err();
    assert!(err.contains("positive integer"), "{err}");
    let neg_toml =
        "[layer]\nc_in = 1\nh_in = -8\nw_in = 8\nh_k = 3\nw_k = 3\nn = 1\n";
    let err = ExperimentConfig::from_toml(neg_toml).unwrap_err();
    assert!(err.contains("got -8"), "{err}");
    // malformed --faults spec
    assert!(FaultModel::from_spec("dma=not-a-rate").is_err());
    assert!(FaultModel::from_spec("bogus-key=1").is_err());
    // unknown preset: same name rejected by the wire with the same class
    let err = parse_request(r#"{"op":"plan","networks":["vgg99"]}"#).unwrap_err();
    assert_eq!(err.kind, ErrorKind::Malformed);
    assert!(err.message.contains("vgg99"));
    let err = parse_request(r#"{"op":"simulate","layer":"vgg99"}"#).unwrap_err();
    assert_eq!(err.kind, ErrorKind::Malformed);
}

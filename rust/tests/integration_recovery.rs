//! Integration tests for degraded-mode planning: batches that survive
//! crashing portfolio lanes, poisoned cache shards, and memory-shrink fault
//! storms — always returning a complete, deterministic set of plans with
//! the damage surfaced in the batch counters.

use std::path::PathBuf;

use convoffload::config::network_preset;
use convoffload::planner::{
    AcceleratorSpec, BatchPlanner, ChaosSpec, PlanOptions, ShardedStrategyCache,
};
use convoffload::platform::{FaultModel, OverlapMode};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "convoffload-recovery-it-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn quick_options() -> PlanOptions {
    PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(4),
        seed: 2026,
        anneal_iters: 1_500,
        anneal_starts: 2,
        threads: 0,
        overlap: OverlapMode::Sequential,
        dma_channels: 1,
        compute_units: 1,
    }
}

fn zoo() -> Vec<convoffload::config::NetworkPreset> {
    vec![
        network_preset("lenet5").unwrap(),
        network_preset("lenet5").unwrap(),
        network_preset("resnet8").unwrap(),
        network_preset("mobilenet_slim").unwrap(),
    ]
}

/// The acceptance scenario: one deliberately panicking portfolio lane *and*
/// one poisoned cache shard, in the same batch. Every network still gets a
/// plan, the winners avoid the crashed lane, and both kinds of damage are
/// surfaced (`panicked_lanes`, `quarantined_shards`) rather than swallowed.
#[test]
fn chaotic_batch_with_poisoned_shard_still_plans_every_network() {
    let dir = tmp_dir("chaos");
    let nets = zoo();
    // One shard so the poison provably sits on the path of every lookup.
    let cache = ShardedStrategyCache::open_with(&dir, 1, 64).unwrap();
    cache.chaos_poison_shard(0);

    let report = BatchPlanner::with_cache(quick_options(), cache)
        .with_chaos(ChaosSpec { panic_lane: Some("greedy".into()) })
        .plan_batch(&nets)
        .unwrap();

    assert_eq!(report.plans.len(), nets.len(), "every network got a plan");
    for plan in &report.plans {
        assert!(!plan.layers.is_empty(), "{}", plan.network);
        assert!(plan.total_duration > 0);
        for lp in &plan.layers {
            assert!(
                !lp.winner.starts_with("greedy"),
                "{}/{}: crashed lane won its race",
                plan.network,
                lp.stage
            );
        }
    }
    // One panic per unique problem raced (7 in the zoo batch).
    assert_eq!(report.stats.panicked_lanes, 7);
    assert!(
        report.stats.cache.quarantined_shards >= 1,
        "the poisoned shard must be quarantined, not hidden"
    );

    // The damaged batch still warmed the store: a clean planner over the
    // same directory replays everything with zero annealing.
    let warm = BatchPlanner::with_cache(
        quick_options(),
        ShardedStrategyCache::open_with(&dir, 1, 64).unwrap(),
    )
    .plan_batch(&nets)
    .unwrap();
    assert_eq!(warm.stats.store_hits, 7);
    assert_eq!(warm.stats.anneal_iters_run, 0);
    assert_eq!(warm.stats.panicked_lanes, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Chaos is deterministic: two identical chaotic batches agree on every
/// duration, winner and counter — losing a lane must not introduce any
/// scheduling-dependent tie-breaks.
#[test]
fn chaotic_batches_are_deterministic() {
    let nets = zoo();
    let chaos = ChaosSpec { panic_lane: Some("zigzag".into()) };
    let a = BatchPlanner::new(quick_options())
        .with_chaos(chaos.clone())
        .plan_batch(&nets)
        .unwrap();
    let b = BatchPlanner::new(quick_options())
        .with_chaos(chaos)
        .plan_batch(&nets)
        .unwrap();
    assert_eq!(a.stats, b.stats);
    assert_eq!(a.stats.panicked_lanes, 7);
    for (pa, pb) in a.plans.iter().zip(&b.plans) {
        assert_eq!(pa.total_duration, pb.total_duration);
        for (la, lb) in pa.layers.iter().zip(&pb.layers) {
            assert_eq!(la.winner, lb.winner);
            assert_eq!(la.strategy, lb.strategy);
        }
    }
}

/// A crashed lane costs only that lane: the chaotic batch's plans are no
/// worse than the clean batch's wherever another lane had already tied the
/// winner, and never better (losing a candidate cannot improve a race).
#[test]
fn losing_a_lane_never_improves_a_plan() {
    let nets = zoo();
    let clean = BatchPlanner::new(quick_options()).plan_batch(&nets).unwrap();
    let chaotic = BatchPlanner::new(quick_options())
        .with_chaos(ChaosSpec { panic_lane: Some("greedy".into()) })
        .plan_batch(&nets)
        .unwrap();
    for (c, x) in clean.plans.iter().zip(&chaotic.plans) {
        assert!(
            x.total_duration >= c.total_duration,
            "{}: chaos improved the plan ({} < {})",
            c.network,
            x.total_duration,
            c.total_duration
        );
    }
}

/// Concurrent chaotic clients over one shared cache converge: every thread
/// suffers its own lane crashes and shard quarantines yet lands on the same
/// plans, and the directory ends warm and complete.
#[test]
fn concurrent_chaotic_clients_converge() {
    let dir = tmp_dir("concurrent-chaos");
    let nets = zoo();
    let cache = ShardedStrategyCache::open_with(&dir, 1, 64).unwrap();
    cache.chaos_poison_shard(0);
    let mut opts = quick_options();
    opts.threads = 2;

    let totals: Vec<Vec<u64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let cache = cache.clone();
                let opts = opts.clone();
                let nets = &nets;
                scope.spawn(move || {
                    let report = BatchPlanner::with_cache(opts, cache)
                        .with_chaos(ChaosSpec {
                            panic_lane: Some("diagonal".into()),
                        })
                        .plan_batch(nets)
                        .unwrap();
                    report.plans.iter().map(|p| p.total_duration).collect()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for t in &totals[1..] {
        assert_eq!(t, &totals[0], "all chaotic clients must converge");
    }
    let warm = BatchPlanner::with_cache(
        quick_options(),
        ShardedStrategyCache::open_with(&dir, 1, 64).unwrap(),
    )
    .plan_batch(&nets)
    .unwrap();
    assert_eq!(warm.stats.store_hits, 7);
    assert_eq!(warm.stats.anneal_iters_run, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The full degraded-mode pipeline: a shrink-storm fault model forces
/// mid-execution memory loss, the planner re-validates every affected stage
/// against the reduced budget and degrades it (re-group, re-race or
/// serialize) — and the batch still returns a complete plan for every
/// network, deterministically, with `degraded_stages` surfaced.
#[test]
fn shrink_storm_batch_degrades_gracefully_and_deterministically() {
    let nets = zoo();
    let m = FaultModel {
        shrink_rate: 1.0,
        shrink_elements: 8,
        ..FaultModel::none()
    }
    .with_seed(7);
    let mut opts = quick_options();
    opts.overlap = OverlapMode::DoubleBuffered;
    let a = BatchPlanner::new(opts.clone())
        .with_faults(m)
        .plan_batch(&nets)
        .unwrap();
    assert_eq!(a.plans.len(), nets.len());
    assert!(a.stats.degraded_stages > 0, "a rate-1.0 storm must degrade");
    let mut saw_degraded_winner = false;
    for plan in &a.plans {
        assert!(plan.total_duration > 0, "{}", plan.network);
        for lp in &plan.layers {
            saw_degraded_winner |= lp.winner.contains("+regroup")
                || lp.winner.contains("+rerace")
                || lp.winner.contains("+serialize");
        }
    }
    assert!(saw_degraded_winner, "degraded stages must mark their winners");

    let b = BatchPlanner::new(opts)
        .with_faults(m)
        .plan_batch(&nets)
        .unwrap();
    assert_eq!(a.stats, b.stats);
    for (pa, pb) in a.plans.iter().zip(&b.plans) {
        assert_eq!(pa.total_duration, pb.total_duration);
        for (la, lb) in pa.layers.iter().zip(&pb.layers) {
            assert_eq!(la.winner, lb.winner);
            assert_eq!(la.strategy, lb.strategy);
        }
    }
}

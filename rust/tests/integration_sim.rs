//! Integration tests over the simulator: strategies × layers × policies,
//! config-driven runs, viz consistency, and failure injection.

use convoffload::config::{layer_preset, ExperimentConfig};
use convoffload::conv::ConvLayer;
use convoffload::platform::{Accelerator, Platform};
use convoffload::sim::{RustOracleBackend, SimError, Simulator};
use convoffload::strategy::{self, WritebackPolicy};

#[test]
fn all_builtin_strategies_on_all_small_presets() {
    for preset in convoffload::config::list_presets() {
        let layer = preset.layer;
        if layer.n_patches() > 150 {
            continue; // keep test time bounded; big layers covered by fig tests
        }
        for group in [1usize, 2, 4] {
            let acc = Accelerator::for_group_size(&layer, group);
            let sim = Simulator::new(layer, Platform::new(acc));
            for s in [
                strategy::row_by_row(&layer, group),
                strategy::zigzag(&layer, group),
                strategy::hilbert(&layer, group),
                strategy::diagonal(&layer, group),
            ] {
                let r = sim.run(&s).unwrap_or_else(|e| {
                    panic!("{} on {} g{group}: {e}", s.name, preset.name)
                });
                assert_eq!(r.n_compute_steps() as usize, s.n_steps());
                assert!(r.peak_occupancy <= acc.size_mem);
                // every input element is loaded at least once
                assert!(r.total_loaded() >= layer.input_dims().len() as u64);
            }
        }
    }
}

#[test]
fn functional_on_strided_and_rectangular_layers() {
    // strided + non-square cases the examples don't cover
    for (layer, group) in [
        (ConvLayer::new(1, 9, 7, 3, 3, 2, 2, 2).unwrap(), 2),
        (ConvLayer::new(3, 6, 10, 3, 3, 1, 1, 1).unwrap(), 3),
        (ConvLayer::new(2, 8, 8, 5, 5, 3, 1, 1).unwrap(), 2),
        (ConvLayer::new(1, 7, 7, 1, 1, 4, 1, 1).unwrap(), 4), // 1x1 kernels
        (ConvLayer::new(2, 6, 6, 3, 3, 2, 3, 3).unwrap(), 2), // disjoint patches
    ] {
        let acc = Accelerator::for_group_size(&layer, group);
        let sim = Simulator::new(layer, Platform::new(acc));
        let input = convoffload::conv::reference::synth_tensor(layer.input_dims().len(), 17);
        let kernels = convoffload::conv::reference::synth_tensor(layer.kernel_elements(), 18);
        let mut backend = RustOracleBackend;
        for s in [strategy::zigzag(&layer, group), strategy::diagonal(&layer, group)] {
            let r = sim
                .run_functional(&s, &input, &kernels, &mut backend)
                .unwrap_or_else(|e| panic!("{} on {layer}: {e}", s.name));
            assert_eq!(r.functional_ok(1e-4), Some(true), "{} on {layer}", s.name);
        }
    }
}

#[test]
fn writeback_policies_trade_memory_for_nothing_in_duration() {
    let layer = layer_preset("example1").unwrap().layer;
    let group = 2;
    let mut acc = Accelerator::for_group_size(&layer, group);
    acc.t_w = 3;
    // at-end keeps all outputs on chip → bigger memory needed
    acc.size_mem += (layer.n_patches() * layer.c_out()) as u64;
    let sim = Simulator::new(layer, Platform::new(acc));

    let mut every = strategy::zigzag(&layer, group);
    every.writeback = WritebackPolicy::EveryStep;
    let mut at_end = strategy::zigzag(&layer, group);
    at_end.writeback = WritebackPolicy::AtEnd;

    let r_every = sim.run(&every).unwrap();
    let r_end = sim.run(&at_end).unwrap();
    // same total elements written → same duration under the linear model
    assert_eq!(r_every.duration, r_end.duration);
    assert_eq!(
        r_every.totals.total.written_elements,
        r_end.totals.total.written_elements
    );
    // but deferred write-back has a strictly larger peak
    assert!(r_end.peak_occupancy > r_every.peak_occupancy);
}

#[test]
fn undersized_memory_is_rejected() {
    let layer = layer_preset("example1").unwrap().layer;
    let mut acc = Accelerator::for_group_size(&layer, 2);
    acc.size_mem = layer.kernel_elements() as u64; // no room for any patch
    let sim = Simulator::new(layer, Platform::new(acc));
    match sim.run(&strategy::zigzag(&layer, 2)) {
        Err(SimError::Step { .. }) => {}
        other => panic!("expected step failure, got {other:?}"),
    }
}

#[test]
fn dram_too_small_is_rejected() {
    let layer = layer_preset("example1").unwrap().layer;
    let acc = Accelerator::for_group_size(&layer, 2);
    let mut platform = Platform::new(acc);
    platform.dram_size = 10;
    let sim = Simulator::new(layer, platform);
    match sim.run(&strategy::zigzag(&layer, 2)) {
        Err(SimError::DramTooSmall) => {}
        other => panic!("expected DramTooSmall, got {other:?}"),
    }
}

#[test]
fn experiment_config_drives_simulation() {
    let cfg = ExperimentConfig::from_toml(
        r#"
name = "itest"

[layer]
preset = "paper-sweep-8"

[accelerator]
group_size = 3
"#,
    )
    .unwrap();
    let sim = Simulator::new(cfg.layer, Platform::new(cfg.accelerator));
    let s = strategy::zigzag(&cfg.layer, cfg.group_size);
    let r = sim.run(&s).unwrap();
    assert!(r.duration > 0);
}

#[test]
fn csv_loaded_strategy_simulates_identically() {
    let layer = layer_preset("example1").unwrap().layer;
    let acc = Accelerator::for_group_size(&layer, 2);
    let sim = Simulator::new(layer, Platform::new(acc));
    let original = strategy::zigzag(&layer, 2);
    let reloaded = strategy::strategy_from_csv(
        "reloaded",
        &strategy::strategy_to_csv(&original),
    )
    .unwrap();
    let a = sim.run(&original).unwrap();
    let b = sim.run(&reloaded).unwrap();
    assert_eq!(a.duration, b.duration);
    assert_eq!(a.total_loaded(), b.total_loaded());
    assert_eq!(a.peak_occupancy, b.peak_occupancy);
}

#[test]
fn viz_outputs_match_strategy_structure() {
    let layer = layer_preset("example1").unwrap().layer;
    let s = strategy::row_by_row(&layer, 2);
    let steps = s.compile(&layer);
    let ascii = convoffload::viz::render_strategy_ascii(&layer, &steps);
    assert_eq!(ascii.matches("step ").count(), steps.len());
    let svg = convoffload::viz::render_strategy_svg(&layer, &steps, "t");
    assert_eq!(
        svg.matches("<rect").count(),
        steps.len() * layer.n_pixels() + 4 // + legend swatches
    );
}

#[test]
fn trace_json_is_parseable_and_complete() {
    let layer = layer_preset("paper-sweep-8").unwrap().layer;
    let acc = Accelerator::for_group_size(&layer, 2);
    let sim = Simulator::new(layer, Platform::new(acc));
    let r = sim.run(&strategy::zigzag(&layer, 2)).unwrap();
    let json_text = r.to_json().to_string_pretty();
    let parsed = convoffload::util::json::parse(&json_text).unwrap();
    assert_eq!(
        parsed.get("n_steps").unwrap().as_u64(),
        Some(r.totals.n_steps)
    );
    assert_eq!(
        parsed.get("steps").unwrap().as_arr().unwrap().len(),
        r.steps.len()
    );
}

//! Integration tests for the batch planning service: the pinned model-zoo
//! batch with exact dedup/cache counters, warm-path zero-anneal replay,
//! shard corruption tolerance, concurrent batch clients, and overlap-mode
//! isolation under the sharded cache.

use std::path::PathBuf;

use convoffload::config::network_preset;
use convoffload::planner::{
    AcceleratorSpec, BatchPlanner, NetworkPlanner, PlanOptions, ShardedStrategyCache,
};
use convoffload::platform::OverlapMode;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "convoffload-batch-it-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn quick_options() -> PlanOptions {
    PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(4),
        seed: 2026,
        anneal_iters: 1_500,
        anneal_starts: 2,
        threads: 0,
        overlap: OverlapMode::Sequential,
        dma_channels: 1,
        compute_units: 1,
    }
}

/// The model-zoo batch of EXPERIMENTS.md: two LeNets, ResNet-8 and the
/// depthwise/dilated trunk.
fn zoo() -> Vec<convoffload::config::NetworkPreset> {
    vec![
        network_preset("lenet5").unwrap(),
        network_preset("lenet5").unwrap(),
        network_preset("resnet8").unwrap(),
        network_preset("mobilenet_slim").unwrap(),
    ]
}

/// The acceptance batch: `[lenet5, lenet5, resnet8, mobilenet_slim]` has 10
/// stages but only 7 distinct planning problems — the second LeNet dedupes
/// both stages cross-network, and ResNet-8's twin stage-2 block dedupes one
/// stage intra-network. Counters are pinned *exactly*; the per-network plans
/// must reproduce the pinned sequential baselines (7100 / 27644 / 3568) and
/// match planning each network alone.
#[test]
fn zoo_batch_dedupes_and_reproduces_the_pinned_baselines() {
    let nets = zoo();
    let report = BatchPlanner::new(quick_options()).plan_batch(&nets).unwrap();
    let s = &report.stats;
    assert_eq!(s.networks, 4);
    assert_eq!(s.stages_total, 10);
    assert_eq!(s.unique_problems, 7);
    assert_eq!(s.dedup_hits, 3);
    assert_eq!(s.cross_network_dedup_hits, 2, "second lenet5 dedupes both stages");
    assert_eq!(s.store_misses, 7, "no persistence: every unique problem races");
    assert_eq!(s.store_hits, 0);
    assert!(s.anneal_iters_run > 0);

    // Pinned sequential baselines, same bounds as the solo planner tests.
    let totals = [7100u64, 7100, 27644, 3568];
    for (plan, &total) in report.plans.iter().zip(&totals) {
        assert!(
            plan.total_duration <= total,
            "{}: {} cycles > pinned baseline {total}",
            plan.network,
            plan.total_duration
        );
    }
    // The twin LeNet rode the first one's races entirely.
    assert_eq!(report.plans[0].cache_misses, 2);
    assert_eq!(report.plans[1].cache_hits, 2);
    assert_eq!(report.plans[1].cache_misses, 0);
    assert_eq!(report.plans[1].anneal_iters_run, 0);
    assert_eq!(
        report.plans[0].total_duration,
        report.plans[1].total_duration
    );
    // ResNet-8's intra-network twin still dedupes inside the batch.
    assert_eq!(report.plans[2].cache_misses, 2);
    assert_eq!(report.plans[2].cache_hits, 1);

    // Batch results are bit-identical to planning each network alone.
    for (preset, plan) in nets.iter().zip(&report.plans) {
        let solo = NetworkPlanner::new(quick_options()).plan(preset).unwrap();
        assert_eq!(plan.total_duration, solo.total_duration, "{}", preset.name);
        for (a, b) in plan.layers.iter().zip(&solo.layers) {
            assert_eq!(a.strategy, b.strategy, "{}/{}", preset.name, a.stage);
            assert_eq!(a.winner, b.winner);
            assert_eq!(a.loaded_pixels, b.loaded_pixels);
        }
    }
}

/// The same zoo batch under the double-buffered objective reproduces the
/// pinned overlapped baselines (6883 / 27272 / 3554) with the same dedup
/// accounting.
#[test]
fn zoo_batch_reproduces_the_overlapped_baselines() {
    let mut opts = quick_options();
    opts.overlap = OverlapMode::DoubleBuffered;
    let report = BatchPlanner::new(opts).plan_batch(&zoo()).unwrap();
    assert_eq!(report.stats.unique_problems, 7);
    assert_eq!(report.stats.cross_network_dedup_hits, 2);
    let totals = [6883u64, 6883, 27272, 3554];
    for (plan, &total) in report.plans.iter().zip(&totals) {
        assert!(
            plan.total_duration <= total,
            "{}: overlapped {} cycles > pinned baseline {total}",
            plan.network,
            plan.total_duration
        );
        assert!(plan.total_duration <= plan.total_sequential_duration);
    }
}

/// Batch determinism across thread counts: the shared race pool changes
/// scheduling, never results or counters.
#[test]
fn zoo_batch_is_deterministic_across_thread_counts() {
    let nets = zoo();
    let mut opts = quick_options();
    let base = BatchPlanner::new(opts.clone()).plan_batch(&nets).unwrap();
    for threads in [1usize, 2, 8] {
        opts.threads = threads;
        let again = BatchPlanner::new(opts.clone()).plan_batch(&nets).unwrap();
        assert_eq!(again.stats, base.stats, "threads={threads}");
        for (a, b) in base.plans.iter().zip(&again.plans) {
            assert_eq!(a.total_duration, b.total_duration, "threads={threads}");
            for (la, lb) in a.layers.iter().zip(&b.layers) {
                assert_eq!(la.strategy, lb.strategy, "threads={threads}");
                assert_eq!(la.winner, lb.winner, "threads={threads}");
            }
        }
    }
}

/// The warm-path acceptance contract: a second identical batch over the same
/// sharded cache directory serves every unique problem from the store and
/// performs **zero** annealing iterations; counters are asserted exactly.
#[test]
fn second_identical_zoo_batch_is_all_hits_and_zero_anneal() {
    let dir = tmp_dir("warm");
    let nets = zoo();
    let cache = ShardedStrategyCache::open(&dir).unwrap();
    let planner = BatchPlanner::with_cache(quick_options(), cache);

    let cold = planner.plan_batch(&nets).unwrap();
    assert_eq!(cold.stats.unique_problems, 7);
    assert_eq!(cold.stats.store_misses, 7);
    assert_eq!(cold.stats.store_hits, 0);
    assert!(cold.stats.anneal_iters_run > 0);
    // Every unique problem was a (counted) miss on its first store lookup.
    assert_eq!(cold.stats.cache.misses, 7);
    assert_eq!(cold.stats.cache.hits, 0);
    assert_eq!(cold.stats.cache.evictions, 0);
    assert_eq!(cold.stats.cache.corrupt_shards, 0);

    let warm = planner.plan_batch(&nets).unwrap();
    assert_eq!(warm.stats.store_hits, 7, "all unique problems served warm");
    assert_eq!(warm.stats.store_misses, 0);
    assert_eq!(warm.stats.anneal_iters_run, 0, "warm batch must not anneal");
    // Counters accumulate across the two calls on the shared cache.
    assert_eq!(warm.stats.cache.hits, 7);
    assert_eq!(warm.stats.cache.misses, 7);
    for (a, b) in cold.plans.iter().zip(&warm.plans) {
        assert_eq!(a.total_duration, b.total_duration);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.strategy, lb.strategy);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The warm path survives a fresh process: a new cache instance over the
/// same directory (cold in-memory state, warm disk) still serves everything.
#[test]
fn warm_batch_survives_a_fresh_cache_instance() {
    let dir = tmp_dir("reopen");
    let nets = zoo();
    BatchPlanner::with_cache(quick_options(), ShardedStrategyCache::open(&dir).unwrap())
        .plan_batch(&nets)
        .unwrap();
    let warm = BatchPlanner::with_cache(
        quick_options(),
        ShardedStrategyCache::open(&dir).unwrap(),
    )
    .plan_batch(&nets)
    .unwrap();
    assert_eq!(warm.stats.store_hits, 7);
    assert_eq!(warm.stats.anneal_iters_run, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A truncated shard file (simulated partial write / crash) loads as misses
/// for its keys only: the batch silently re-races those problems, the other
/// shards keep serving hits, and the re-planned batch repairs the shard.
#[test]
fn corrupted_shard_is_tolerated_and_repaired_by_the_next_batch() {
    let dir = tmp_dir("corrupt");
    let nets = zoo();
    BatchPlanner::with_cache(quick_options(), ShardedStrategyCache::open(&dir).unwrap())
        .plan_batch(&nets)
        .unwrap();

    // Truncate every populated shard file's tail — worse than any single
    // crash would do — leaving valid JSON in none of them.
    let mut truncated = 0;
    for f in std::fs::read_dir(&dir).unwrap() {
        let p = f.unwrap().path();
        let name = p.file_name().unwrap().to_string_lossy().into_owned();
        if !name.starts_with("shard-") {
            continue;
        }
        let text = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, &text[..text.len() / 3]).unwrap();
        truncated += 1;
    }
    assert!(truncated > 0, "expected populated shard files");

    let cache = ShardedStrategyCache::open(&dir).unwrap();
    let planner = BatchPlanner::with_cache(quick_options(), cache);
    let replanned = planner.plan_batch(&nets).unwrap();
    assert_eq!(
        replanned.stats.store_misses, 7,
        "all entries lost -> all unique problems re-race (never a panic)"
    );
    assert_eq!(replanned.stats.cache.corrupt_shards as usize, truncated);
    // The re-planned batch rewrote complete shards: a fresh instance is warm.
    let warm = BatchPlanner::with_cache(
        quick_options(),
        ShardedStrategyCache::open(&dir).unwrap(),
    )
    .plan_batch(&nets)
    .unwrap();
    assert_eq!(warm.stats.store_hits, 7, "corruption was repaired");
    assert_eq!(warm.stats.cache.corrupt_shards, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Concurrent batch clients over one shared cache converge: every thread
/// gets the same plans, and the directory ends warm and complete. (Writers
/// racing on the same keys are serialized per shard; files are written via
/// temp + atomic rename, so no interleaving can surface a torn file.)
#[test]
fn concurrent_batch_clients_over_one_cache_converge() {
    let dir = tmp_dir("concurrent");
    let nets = zoo();
    let cache = ShardedStrategyCache::open(&dir).unwrap();
    let mut opts = quick_options();
    opts.threads = 2; // keep 4 clients x 2 workers bounded

    let totals: Vec<Vec<u64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let cache = cache.clone();
                let opts = opts.clone();
                let nets = &nets;
                scope.spawn(move || {
                    let report = BatchPlanner::with_cache(opts, cache)
                        .plan_batch(nets)
                        .unwrap();
                    report.plans.iter().map(|p| p.total_duration).collect()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for t in &totals[1..] {
        assert_eq!(t, &totals[0], "all clients must converge on one answer");
    }
    // The directory is complete: a fresh instance runs fully warm.
    let warm = BatchPlanner::with_cache(
        quick_options(),
        ShardedStrategyCache::open(&dir).unwrap(),
    )
    .plan_batch(&nets)
    .unwrap();
    assert_eq!(warm.stats.store_hits, 7);
    assert_eq!(warm.stats.anneal_iters_run, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Overlap modes are distinct planning problems even under concurrent batch
/// load on one directory: a sequential and a double-buffered client never
/// serve each other's entries, and both end with their own warm set.
#[test]
fn overlap_modes_stay_isolated_under_concurrent_batches() {
    let dir = tmp_dir("modes");
    let nets = zoo();
    let cache = ShardedStrategyCache::open(&dir).unwrap();
    let mut seq_opts = quick_options();
    seq_opts.threads = 2;
    let mut db_opts = seq_opts.clone();
    db_opts.overlap = OverlapMode::DoubleBuffered;

    std::thread::scope(|scope| {
        let c1 = cache.clone();
        let n1 = &nets;
        let o1 = seq_opts.clone();
        let seq = scope.spawn(move || {
            BatchPlanner::with_cache(o1, c1).plan_batch(n1).unwrap()
        });
        let c2 = cache.clone();
        let o2 = db_opts.clone();
        let n2 = &nets;
        let db = scope.spawn(move || {
            BatchPlanner::with_cache(o2, c2).plan_batch(n2).unwrap()
        });
        let seq = seq.join().unwrap();
        let db = db.join().unwrap();
        assert_eq!(seq.stats.store_misses, 7, "nothing cross-served");
        assert_eq!(db.stats.store_misses, 7, "nothing cross-served");
        for plan in &db.plans {
            assert!(plan.total_duration <= plan.total_sequential_duration);
        }
    });
    // Both modes are now warm in one directory (14 distinct entries).
    for opts in [seq_opts, db_opts] {
        let warm = BatchPlanner::with_cache(
            opts,
            ShardedStrategyCache::open(&dir).unwrap(),
        )
        .plan_batch(&nets)
        .unwrap();
        assert_eq!(warm.stats.store_hits, 7);
        assert_eq!(warm.stats.anneal_iters_run, 0);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

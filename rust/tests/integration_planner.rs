//! Integration tests for the network-level planner (the three-layer
//! contract): determinism under arbitrary thread schedules, cache hits with
//! zero anneal work on re-planning, and the real network presets.

use std::path::PathBuf;

use convoffload::config::network_preset;
use convoffload::planner::{AcceleratorSpec, NetworkPlanner, PlanOptions, StrategyCache};
use convoffload::platform::OverlapMode;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "convoffload-planner-it-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn quick_options() -> PlanOptions {
    PlanOptions {
        accelerator: AcceleratorSpec::PerLayerGroup(4),
        seed: 2026,
        anneal_iters: 1_500,
        anneal_starts: 2,
        threads: 0,
        overlap: OverlapMode::Sequential,
        dma_channels: 1,
        compute_units: 1,
    }
}

/// Same seed ⇒ identical plan, regardless of how the portfolio race is
/// scheduled over threads.
#[test]
fn lenet5_plan_is_deterministic_per_seed() {
    let preset = network_preset("lenet5").unwrap();
    let mut opts = quick_options();
    opts.threads = 1;
    let a = NetworkPlanner::new(opts.clone()).plan(&preset).unwrap();
    opts.threads = 8;
    let b = NetworkPlanner::new(opts).plan(&preset).unwrap();
    assert_eq!(a.layers.len(), b.layers.len());
    for (x, y) in a.layers.iter().zip(&b.layers) {
        assert_eq!(x.strategy, y.strategy);
        assert_eq!(x.winner, y.winner);
        assert_eq!(x.loaded_pixels, y.loaded_pixels);
    }
    assert_eq!(a.total_duration, b.total_duration);
}

/// The acceptance contract of the strategy cache: a second `plan` call hits
/// for every layer, performs zero anneal iterations, and returns the
/// identical plan.
#[test]
fn replanning_hits_the_cache_with_zero_anneal_iterations() {
    let dir = tmp_dir("cache-hit");
    let preset = network_preset("lenet5").unwrap();
    let planner =
        NetworkPlanner::with_cache(quick_options(), StrategyCache::open(&dir).unwrap());
    let first = planner.plan(&preset).unwrap();
    assert_eq!(first.cache_misses, 2);
    assert_eq!(first.cache_hits, 0);
    assert!(first.anneal_iters_run > 0);

    let second = planner.plan(&preset).unwrap();
    assert_eq!(second.cache_hits, first.layers.len());
    assert_eq!(second.cache_misses, 0);
    assert_eq!(second.anneal_iters_run, 0, "cache hits must skip annealing");
    for (x, y) in first.layers.iter().zip(&second.layers) {
        assert_eq!(x.strategy, y.strategy);
        assert_eq!(x.loaded_pixels, y.loaded_pixels);
        assert!(y.cache_hit);
    }
    assert_eq!(first.total_duration, second.total_duration);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The cache is on disk: a fresh planner instance over the same directory
/// reuses the stored strategies.
#[test]
fn cache_persists_across_planner_instances() {
    let dir = tmp_dir("cache-persist");
    let preset = network_preset("lenet5").unwrap();
    let first =
        NetworkPlanner::with_cache(quick_options(), StrategyCache::open(&dir).unwrap())
            .plan(&preset)
            .unwrap();
    let second =
        NetworkPlanner::with_cache(quick_options(), StrategyCache::open(&dir).unwrap())
            .plan(&preset)
            .unwrap();
    assert_eq!(second.cache_misses, 0);
    assert_eq!(second.anneal_iters_run, 0);
    for (x, y) in first.layers.iter().zip(&second.layers) {
        assert_eq!(x.strategy, y.strategy);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A cache file whose stored objective no longer matches the recomputed one
/// (stale writer, hand edit) must be re-raced, not trusted.
#[test]
fn stale_objective_in_cache_is_replanned() {
    let dir = tmp_dir("cache-stale");
    let preset = network_preset("lenet5").unwrap();
    let planner =
        NetworkPlanner::with_cache(quick_options(), StrategyCache::open(&dir).unwrap());
    let first = planner.plan(&preset).unwrap();
    for f in std::fs::read_dir(&dir).unwrap() {
        let p = f.unwrap().path();
        let text = std::fs::read_to_string(&p).unwrap();
        // prefix a digit: 2385 -> 92385 etc., keeping the JSON valid
        let bumped = text.replace("\"loaded_pixels\": ", "\"loaded_pixels\": 9");
        assert_ne!(bumped, text, "expected a loaded_pixels field in {p:?}");
        std::fs::write(&p, bumped).unwrap();
    }
    let second = planner.plan(&preset).unwrap();
    assert_eq!(second.cache_misses, 2, "stale objectives must re-race");
    for (x, y) in first.layers.iter().zip(&second.layers) {
        assert_eq!(x.strategy, y.strategy, "re-race is deterministic");
        assert_eq!(x.loaded_pixels, y.loaded_pixels);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The cache key covers the portfolio configuration, so a different seed is
/// a different problem — never served from a stale entry.
#[test]
fn changing_the_seed_misses_the_cache() {
    let dir = tmp_dir("cache-seed");
    let preset = network_preset("lenet5").unwrap();
    let mut opts = quick_options();
    NetworkPlanner::with_cache(opts.clone(), StrategyCache::open(&dir).unwrap())
        .plan(&preset)
        .unwrap();
    opts.seed += 1;
    let plan = NetworkPlanner::with_cache(opts, StrategyCache::open(&dir).unwrap())
        .plan(&preset)
        .unwrap();
    assert_eq!(
        plan.cache_misses, 2,
        "different portfolio config must be a different key"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The EXPERIMENTS.md baseline must never regress: the anneal-free
/// heuristic-portfolio winner is analytic (lenet5 7100 cycles / resnet8
/// 27644; per-layer loaded pixels 2385+324 and 1988+508+508), the heuristic
/// lanes always race, and the reduction keeps them on ties — so *any*
/// planner configuration must do at least this well. This pins the PR-2
/// acceptance bar (delta-evaluated search must not change what the planner
/// achieves) in CI, independent of anneal budget.
#[test]
fn planner_never_regresses_the_analytic_baseline() {
    for (net, per_layer_px, total) in [
        ("lenet5", vec![2385u64, 324], 7100u64),
        ("resnet8", vec![1988, 508, 508], 27644),
    ] {
        let preset = network_preset(net).unwrap();
        let plan = NetworkPlanner::new(quick_options()).plan(&preset).unwrap();
        assert_eq!(plan.layers.len(), per_layer_px.len(), "{net}");
        for (lp, &bound) in plan.layers.iter().zip(&per_layer_px) {
            assert!(
                lp.loaded_pixels <= bound,
                "{net}/{}: {} loaded pixels > analytic baseline {bound}",
                lp.stage,
                lp.loaded_pixels
            );
        }
        assert!(
            plan.total_duration <= total,
            "{net}: {} cycles > analytic baseline {total}",
            plan.total_duration
        );
    }
}

/// The generalized-zoo baseline added with dilation/groups support: the
/// mobilenet_slim preset (depthwise 3x3 s2 → pointwise 1x1 → dilated 3x3)
/// must never do worse than the analytic anneal-free portfolio winners.
/// The numbers are cross-checked from an independent code base by the
/// Python oracle (python/tests/test_oracle_sim.py::TestPlannerBaselines):
/// dw3 = 325 loaded px (hilbert), pw1 = 64 (disjoint 1x1 patches),
/// dil3 = 165 (greedy; the scan orders pay 288 because dilation holes break
/// adjacent-patch reuse) — total 3568 cycles at group 4.
#[test]
fn mobilenet_slim_never_regresses_the_analytic_baseline() {
    let preset = network_preset("mobilenet_slim").unwrap();
    let plan = NetworkPlanner::new(quick_options()).plan(&preset).unwrap();
    let per_layer_px = [325u64, 64, 165];
    assert_eq!(plan.layers.len(), per_layer_px.len());
    for (lp, &bound) in plan.layers.iter().zip(&per_layer_px) {
        assert!(
            lp.loaded_pixels <= bound,
            "mobilenet_slim/{}: {} loaded pixels > analytic baseline {bound}",
            lp.stage,
            lp.loaded_pixels
        );
    }
    assert!(
        plan.total_duration <= 3568,
        "mobilenet_slim: {} cycles > analytic baseline 3568",
        plan.total_duration
    );
    // The pointwise stage has zero patch overlap: 64 loads is optimal, so
    // the planner must hit it exactly.
    assert_eq!(plan.layers[1].loaded_pixels, 64);
}

/// The overlapped-offload baseline (PR 5): racing the same presets under
/// `OverlapMode::DoubleBuffered` must do at least as well as the analytic
/// anneal-free portfolio winner in the makespan metric. The numbers are
/// produced and cross-checked bit-exactly from an independent code base by
/// the Python oracle
/// (`python/tests/test_oracle_sim.py::TestOverlappedPlannerBaselines`):
/// per-stage winner makespans lenet5 = [2538 (greedy), 4345 (hilbert)],
/// resnet8 = [6402, 10435, 10435] (greedy), mobilenet_slim = [1352
/// (hilbert), 304 (row-by-row), 1898 (greedy)] — totals 6883 / 27272 /
/// 3554 cycles vs the sequential 7100 / 27644 / 3568. Sequential-mode
/// plans are untouched (pinned by the baselines above).
#[test]
fn double_buffered_planner_never_regresses_the_overlap_baseline() {
    for (net, per_stage_makespan, total, sequential_total) in [
        ("lenet5", vec![2538u64, 4345], 6883u64, 7100u64),
        ("resnet8", vec![6402, 10435, 10435], 27272, 27644),
        ("mobilenet_slim", vec![1352, 304, 1898], 3554, 3568),
    ] {
        let preset = network_preset(net).unwrap();
        let mut opts = quick_options();
        opts.overlap = OverlapMode::DoubleBuffered;
        let plan = NetworkPlanner::new(opts).plan(&preset).unwrap();
        assert_eq!(plan.layers.len(), per_stage_makespan.len(), "{net}");
        for (lp, &bound) in plan.layers.iter().zip(&per_stage_makespan) {
            assert!(
                lp.duration <= bound,
                "{net}/{}: makespan {} > analytic overlap baseline {bound}",
                lp.stage,
                lp.duration
            );
            assert!(
                lp.duration <= lp.sequential_duration,
                "{net}/{}: overlapped above sequential",
                lp.stage
            );
        }
        assert!(
            plan.total_duration <= total,
            "{net}: {} cycles > analytic overlap baseline {total}",
            plan.total_duration
        );
        // The overlapped plan must beat (or match) the pinned *sequential*
        // baseline too: hiding transfer time can only help.
        assert!(
            plan.total_duration <= sequential_total,
            "{net}: overlapped {} > sequential baseline {sequential_total}",
            plan.total_duration
        );
    }
}

/// ResNet-8's two stage-2 convolutions share one geometry: the planner races
/// it once and the twin rides the cache even within a single call.
#[test]
fn resnet8_shares_the_stage2_shape() {
    let preset = network_preset("resnet8").unwrap();
    let plan = NetworkPlanner::new(quick_options()).plan(&preset).unwrap();
    assert_eq!(plan.layers.len(), 3);
    assert_eq!(plan.cache_misses, 2);
    assert_eq!(plan.cache_hits, 1);
    assert_eq!(plan.layers[1].strategy, plan.layers[2].strategy);
    assert!(plan.layers[2].cache_hit);
    assert!(!plan.layers[0].cache_hit);
    assert!(plan.total_duration > 0);
    assert_eq!(
        plan.total_duration,
        plan.layers.iter().map(|l| l.duration).sum::<u64>()
    );
}

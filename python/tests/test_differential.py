"""Rust↔Python differential gate.

``rust/tests/differential.rs`` (run by tier-1 ``cargo test``) simulates a
seeded set of fuzz networks — covering stride, dilation, channel groups and
pooling — and writes ``target/differential_cases.json`` with the full specs
plus the Rust simulator's results. This test replays every case through the
independent Python oracle (`oracle_sim`) and asserts bit-equal durations,
loaded elements and step counts.

When the JSON is absent (cargo has not run in this checkout — e.g. a
Python-only dev loop), the whole module skips with a pointer to the
generator; CI wires the two as dependent jobs so the gate always runs there.
Set ``DIFFERENTIAL_CASES=/path/to.json`` to point at a downloaded artifact.
"""

import json
import os
import pathlib

import pytest

import oracle_sim as o

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[2]
_DEFAULT = _REPO_ROOT / "target" / "differential_cases.json"


def _cases_path():
    override = os.environ.get("DIFFERENTIAL_CASES")
    return pathlib.Path(override) if override else _DEFAULT


def _load_cases():
    path = _cases_path()
    if not path.exists():
        pytest.skip(
            f"{path} not found - run `cargo test` (rust/tests/differential.rs "
            "emits it) or set DIFFERENTIAL_CASES"
        )
    with open(path) as f:
        doc = json.load(f)
    # v5 adds per-stage certification expectations — the element-domain
    # communication floor (`comm_lower_bound`) and `optimality_gap`, both
    # replayed bit-exactly by the oracle's independent bound — on top of
    # v4's multi-resource expectations (sampled k DMA channels x m compute
    # units, image batching, per-resource busy totals) and stage-decorrelated
    # fault streams; an older file is a stale artifact from before the
    # certification PR.
    assert doc.get("version") == 5, (
        f"interchange version {doc.get('version')} != 5 - stale "
        f"{path}; re-run `cargo test` to regenerate it"
    )
    # Provenance gate: a green differential signal must mean the *Rust
    # simulator* produced the expected values. Any other generator (a stale
    # or hand-built file) is a broken setup, not a pass.
    generator = doc.get("generator")
    assert generator == "config::fuzz::random_network", (
        f"{path} was written by {generator!r}, not by rust/tests/differential.rs "
        "- re-run `cargo test` to regenerate it"
    )
    return doc["cases"]


def test_case_set_is_large_and_diverse():
    cases = _load_cases()
    assert len(cases) >= 20, f"expected >= 20 cases, got {len(cases)}"
    feats = {"stride": False, "dilation": False, "groups": False, "pool": False}
    for case in cases:
        for st in case["stages"]:
            layer = st["layer"]
            feats["stride"] |= layer["s_h"] > 1 or layer["s_w"] > 1
            feats["dilation"] |= layer["d_h"] > 1 or layer["d_w"] > 1
            feats["groups"] |= layer["groups"] > 1
            feats["pool"] |= st["pool_after"]
    missing = [k for k, v in feats.items() if not v]
    assert not missing, f"case set covers no {missing} scenario"


def test_python_oracle_matches_rust_simulator():
    mismatches = []
    for case in _load_cases():
        got = o.replay_case(case)
        want = case["expected"]
        seed = case["seed"]
        if got["total_duration"] != want["total_duration"]:
            mismatches.append(
                f"seed {seed}: total duration {got['total_duration']} != "
                f"{want['total_duration']}"
            )
        for st, res, exp in zip(case["stages"], got["per_stage"], want["per_stage"]):
            for field in ("duration", "loaded_elements", "n_steps"):
                g = getattr(res, field)
                if g != exp[field]:
                    mismatches.append(
                        f"seed {seed} stage {exp['name']}: {field} {g} != {exp[field]}"
                    )
            # v5 certification expectations: the oracle's independent bound
            # must reproduce the Rust floor and gap bit-exactly (the gap is a
            # quotient of the same two integers on both sides, so float
            # equality is deterministic).
            layer = o.layer_from_json(st["layer"])
            acc = o.accelerator_from_json(st["accelerator"])
            floor = o.comm_lower_bound(layer, acc)["load_element_floor"]
            if exp["comm_lower_bound"] != floor:
                mismatches.append(
                    f"seed {seed} stage {exp['name']}: comm_lower_bound "
                    f"{exp['comm_lower_bound']} != oracle {floor}"
                )
            gap = o.optimality_gap(exp["loaded_elements"], floor)
            if exp["optimality_gap"] != gap:
                mismatches.append(
                    f"seed {seed} stage {exp['name']}: optimality_gap "
                    f"{exp['optimality_gap']} != oracle {gap}"
                )
            if floor > exp["loaded_elements"]:
                mismatches.append(
                    f"seed {seed} stage {exp['name']}: floor {floor} above "
                    f"simulated loads {exp['loaded_elements']}"
                )
    assert not mismatches, "\n".join(mismatches)


def test_python_oracle_matches_rust_overlapped_makespans():
    """The §3.7 double-buffered timeline, replayed independently: bit-equal
    per-stage makespans and resource busy totals on both the case's own
    accelerator and the 2x-memory "roomy" variant (where most prefetches
    succeed, so the overlap path itself — not just the serialization
    fallback — is compared)."""
    mismatches = []
    for case in _load_cases():
        got = o.replay_case(case)
        want = case["expected"]
        seed = case["seed"]
        for key, got_key in (
            ("overlapped", "overlapped"),
            ("overlapped_roomy", "overlapped_roomy"),
        ):
            exp = want[key]
            if sum(r.makespan for r in got[got_key]) != exp["total_makespan"]:
                mismatches.append(
                    f"seed {seed} {key}: total makespan "
                    f"{sum(r.makespan for r in got[got_key])} != {exp['total_makespan']}"
                )
            for res, stage in zip(got[got_key], exp["per_stage"]):
                for field, want_field in (
                    ("makespan", "makespan"),
                    ("sequential_duration", "sequential_duration"),
                    ("dma_busy", "dma_busy"),
                    ("compute_busy", "compute_busy"),
                ):
                    g = getattr(res, field)
                    if g != stage[want_field]:
                        mismatches.append(
                            f"seed {seed} {key} stage {stage['name']}: "
                            f"{field} {g} != {stage[want_field]}"
                        )
    assert not mismatches, "\n".join(mismatches)


def test_python_oracle_matches_rust_multi_resource():
    """The v4 gate: every case carries a sampled resource shape (k DMA
    channels x m compute units, batch of N images) replayed double-buffered
    on the 2x-memory variant. The oracle's independent k x m list scheduler
    must land on bit-equal makespans, batched sequential sums and
    *per-resource* busy totals."""
    mismatches = []
    sampled_shapes = set()
    for case in _load_cases():
        got = o.replay_case(case)
        want = case["expected"]["multi"]
        seed = case["seed"]
        shape = (case["dma_channels"], case["compute_units"], case["batch"])
        sampled_shapes.add(shape)
        assert (want["dma_channels"], want["compute_units"], want["batch"]) == shape
        if got["multi_total"] != want["total_makespan"]:
            mismatches.append(
                f"seed {seed} multi: total makespan {got['multi_total']} != "
                f"{want['total_makespan']}"
            )
        for res, stage in zip(got["multi"], want["per_stage"]):
            for field in (
                "makespan",
                "sequential_duration",
                "dma_busy",
                "compute_busy",
                "dma_busy_per",
                "compute_busy_per",
            ):
                g = getattr(res, field)
                if g != stage[field]:
                    mismatches.append(
                        f"seed {seed} multi stage {stage['name']}: "
                        f"{field} {g} != {stage[field]}"
                    )
    assert not mismatches, "\n".join(mismatches)
    # The sampler must actually exercise the generalization: some case needs
    # more than one channel, more than one unit, and a real batch.
    assert any(k > 1 for k, _, _ in sampled_shapes), "no case sampled k > 1"
    assert any(m > 1 for _, m, _ in sampled_shapes), "no case sampled m > 1"
    assert any(n > 1 for _, _, n in sampled_shapes), "no case sampled batch > 1"


def test_python_oracle_matches_rust_fault_injection():
    """The fault gate: the oracle replays each case's seeded fault streams
    through its own xoshiro256** port and must land on bit-equal faulted
    durations, retry and shrink counts, and WCET bounds — in both duration
    semantics. Since v4 stage ``i`` draws from ``model.for_stage(i)`` on
    both sides. This is the cross-language contract for the whole fault
    subsystem (RNG, stage seed mixing, per-step draw order, retry/jitter
    cost recurrences, the sticky memory-shrink residency fallback, the
    analytic bound)."""
    mismatches = []
    for case in _load_cases():
        want = case["expected"]["faulted"]
        seed = case["seed"]
        model = o.fault_model_from_json(want["model"])
        assert model.is_active(), f"seed {seed}: differential model inert"
        got = o.replay_case_faulted(case, model)

        wseq = want["sequential"]
        for field in ("total_duration", "fault_retries", "mem_shrink_events", "wcet_bound"):
            if got[field] != wseq[field]:
                mismatches.append(
                    f"seed {seed} sequential: {field} {got[field]} != {wseq[field]}"
                )
        for res, exp in zip(got["per_stage"], wseq["per_stage"]):
            for field in ("duration", "fault_retries", "mem_shrink_events", "wcet_bound"):
                g = getattr(res, field)
                if g != exp[field]:
                    mismatches.append(
                        f"seed {seed} sequential stage {exp['name']}: "
                        f"{field} {g} != {exp[field]}"
                    )

        wovl = want["overlapped"]
        if got["overlapped_total"] != wovl["total_makespan"]:
            mismatches.append(
                f"seed {seed} overlapped: total {got['overlapped_total']} != "
                f"{wovl['total_makespan']}"
            )
        for res, exp in zip(got["overlapped"], wovl["per_stage"]):
            for field, want_field in (
                ("makespan", "makespan"),
                ("sequential_duration", "sequential_duration"),
                ("fault_retries", "fault_retries"),
                ("mem_shrink_events", "mem_shrink_events"),
                ("wcet_bound", "wcet_bound"),
            ):
                g = getattr(res, field)
                if g != exp[want_field]:
                    mismatches.append(
                        f"seed {seed} overlapped stage {exp['name']}: "
                        f"{field} {g} != {exp[want_field]}"
                    )
    assert not mismatches, "\n".join(mismatches)


def test_fault_injection_actually_fires_somewhere():
    """The faulted gate must not be vacuous: across the case set the models
    must inject retries, shrink events and a real duration inflation."""
    retries = shrinks = inflated = 0
    for case in _load_cases():
        want = case["expected"]["faulted"]["sequential"]
        retries += want["fault_retries"]
        shrinks += want["mem_shrink_events"]
        inflated += want["total_duration"] - case["expected"]["total_duration"]
    assert retries > 0, "no case drew a DMA retry - fault path untested"
    assert shrinks > 0, "no case drew a shrink event - shrink path untested"
    assert inflated > 0, "fault injection never inflated a duration"


def test_roomy_variant_actually_overlaps_somewhere():
    """The 2x-memory variant exists to exercise true prefetching: across
    the whole case set at least one stage must hide transfer time (makespan
    strictly below the sequential duration), otherwise the overlap path is
    untested and the gate is vacuous."""
    hidden = 0
    for case in _load_cases():
        for st in case["expected"]["overlapped_roomy"]["per_stage"]:
            hidden += st["sequential_duration"] - st["makespan"]
    assert hidden > 0, "no case hid any transfer time - overlap path untested"


def test_replay_validates_structure_independently():
    """The oracle re-derives stage chaining and patch coverage from the spec
    alone — a malformed case must fail loudly, not silently agree."""
    cases = _load_cases()
    case = json.loads(json.dumps(cases[0]))  # deep copy
    # corrupt: drop a patch from the first stage's first group
    groups = case["stages"][0]["strategy_groups"]
    if len(groups[0]) > 1:
        groups[0] = groups[0][:-1]
    else:
        groups.pop(0)
    with pytest.raises(AssertionError):
        o.replay_case(case)

"""Property and pin tests for the generalized k x m overlap timeline.

The §3.10 multi-resource timeline (``oracle_sim.MultiResourceTimeline``)
must (a) collapse bit-exactly to the §3.7 two-resource recurrence at
k = m = 1, (b) reproduce a hand-computed (k=2, m=1) schedule — the same
pin the Rust side carries in ``step::cost`` — (c) be monotone
non-increasing in k and m, and (d) never beat the resource floor
``max(ceil(total_dma/k), ceil(total_compute/m))``.  The fuzz-seed half of
the collapse property (all 24 seeds x both overlap modes) lives in
``rust/tests/invariants.rs`` and in ``test_differential.py``'s v4 replay;
here the same properties run over the preset zoo, which needs no Rust
artifact.
"""

import itertools

from dataclasses import replace

import oracle_sim as o


def _zoo():
    """Every preset-zoo layer with its default planner grouping."""
    layers = [
        o.Layer(1, 32, 32, 5, 5, 6),
        o.Layer(6, 14, 14, 5, 5, 16),
        o.Layer(3, 34, 34, 3, 3, 16),
        o.Layer(16, 18, 18, 3, 3, 16),
        o.Layer(4, 18, 18, 3, 3, 4, s_h=2, s_w=2, groups=4),
        o.Layer(4, 8, 8, 1, 1, 8),
        o.Layer(8, 12, 12, 3, 3, 8, d_h=2, d_w=2),
    ]
    for layer in layers:
        groups = o.order_to_groups(o.row_major_order(layer), 4)
        yield layer, o.for_group_size(layer, 4), groups


class TestHandComputedPin:
    """The 3-step (k=2, m=1) schedule, phase instant by phase instant —
    mirrored verbatim by ``overlap_timeline_multi_hand_computed_k2`` in
    ``rust/src/step/cost.rs``."""

    PUSHES = [(10, 0, 5, True), (6, 2, 5, True), (6, 2, 5, False), (0, 2, 0, True)]

    def test_k1_m1_baseline_is_the_legacy_chain(self):
        # The same pushes on the scalar timeline pin makespan 34 (the Rust
        # ``overlap_timeline_hand_computed_chain`` values).
        t = o.OverlapTimeline()
        for p in self.PUSHES:
            t.push(*p)
        assert t.makespan() == 34
        assert (t.dma_busy, t.compute_busy) == (28, 15)

    def test_k2_m1_schedule(self):
        t = o.MultiResourceTimeline(2, 1)
        placements = [t.push(*p) for p in self.PUSHES]
        # load channel, write channel, compute unit per step:
        assert placements == [(0, 1, 0), (1, 1, 0), (0, 1, 0), (1, 1, 0)]
        # s2's write waits for compute 1 (ends 15) even though channel 1 is
        # free at 6 — the producer gate; s3 serializes (no prefetch) behind
        # compute 2 (ends 20); the flush write drains compute 3 (ends 31).
        assert t.dma_free == [26, 33]
        assert t.comp_free == [31]
        assert t.makespan() == 33
        assert t.dma_busy_per == [16, 12]
        assert t.compute_busy_per == [15]

    def test_second_channel_helps_this_chain(self):
        t1 = o.OverlapTimeline()
        t2 = o.MultiResourceTimeline(2, 1)
        for p in self.PUSHES:
            t1.push(*p)
            t2.push(*p)
        assert t2.makespan() < t1.makespan()


class TestCollapseToLegacy:
    """(k=1, m=1, batch=1) is bit-identical to the §3.7 recurrence — the
    generalized code path must not perturb a single pinned baseline."""

    def test_zoo_collapse_both_memory_variants(self):
        for layer, acc, groups in _zoo():
            for mem_factor in (1, 2):
                a = replace(acc, size_mem=acc.size_mem * mem_factor)
                legacy = o.simulate_stage_overlapped(layer, a, groups)
                multi = o.simulate_stage_multi(layer, a, groups)
                assert multi.makespan == legacy.makespan
                assert multi.sequential_duration == legacy.sequential_duration
                assert multi.dma_busy == legacy.dma_busy
                assert multi.compute_busy == legacy.compute_busy
                assert multi.n_prefetched == legacy.n_prefetched
                assert multi.dma_busy_per == [legacy.dma_busy]
                assert multi.compute_busy_per == [legacy.compute_busy]

    def test_extra_units_without_batching_change_nothing(self):
        # Within one image the compute steps form a dependency chain, so
        # extra compute units cannot change the makespan at batch=1.
        for layer, acc, groups in _zoo():
            base = o.simulate_stage_multi(layer, acc, groups)
            more = o.simulate_stage_multi(
                layer, replace(acc, compute_units=3), groups
            )
            assert more.makespan == base.makespan


class TestMonotonicityAndFloor:
    GRID = [1, 2, 3]

    def test_monotone_non_increasing_in_k_and_m(self):
        for layer, acc, groups in _zoo():
            for batch in (1, 4):
                span = {}
                for k, m in itertools.product(self.GRID, self.GRID):
                    a = replace(acc, dma_channels=k, compute_units=m)
                    span[(k, m)] = o.simulate_stage_multi(
                        layer, a, groups, batch=batch
                    ).makespan
                for k, m in itertools.product(self.GRID, self.GRID):
                    if k > 1:
                        assert span[(k, m)] <= span[(k - 1, m)], (layer, k, m, batch)
                    if m > 1:
                        assert span[(k, m)] <= span[(k, m - 1)], (layer, k, m, batch)

    def test_resource_floor(self):
        for layer, acc, groups in _zoo():
            for k, m, batch in itertools.product(self.GRID, self.GRID, (1, 4)):
                a = replace(acc, dma_channels=k, compute_units=m)
                r = o.simulate_stage_multi(layer, a, groups, batch=batch)
                floor = max(-(-r.dma_busy // k), -(-r.compute_busy // m))
                assert r.makespan >= floor, (layer, k, m, batch)
                assert r.makespan <= r.sequential_duration, (layer, k, m, batch)


class TestBatching:
    def test_batch_amortizes_kernel_loads(self):
        # N images cost less than N independent runs: kernels load once.
        for layer, acc, groups in _zoo():
            one = o.simulate_stage_multi(layer, acc, groups, batch=1)
            four = o.simulate_stage_multi(layer, acc, groups, batch=4)
            saved = 3 * layer.kernel_elements * acc.t_l
            assert four.sequential_duration == 4 * one.sequential_duration - saved
            assert four.makespan <= 4 * one.makespan

    def test_batch_pipelines_across_compute_units(self):
        # On a compute-bound machine (t_acc dominates the transfers) extra
        # units let consecutive images' compute chains overlap. The
        # for_group_size zoo machines are DMA-bound (t_l = t_acc = 1), so
        # the probe raises t_acc; m=2 alone may not help — round-robin
        # earliest-free placement leaves the "free" unit carrying the
        # previous image's middle compute — but the unit grid must.
        layer = o.Layer(1, 3, 12, 3, 3, 1)
        groups = o.order_to_groups(o.row_major_order(layer), 4)
        acc = o.Accelerator(
            nbop_pe=36, t_acc=100, size_mem=256, t_l=1, t_w=1, dma_channels=2
        )
        spans = [
            o.simulate_stage_multi(
                layer, replace(acc, compute_units=m), groups, batch=4
            ).makespan
            for m in (1, 2, 3)
        ]
        assert spans == sorted(spans, reverse=True)
        assert spans[2] < spans[0], "extra compute units never overlapped images"

    def test_batch_images_are_identical_after_the_first(self):
        # Sequential duration: image 0 pays kernels, images 1..N-1 are
        # identical — so durations grow affinely in N.
        layer, acc, groups = next(iter(_zoo()))
        seq = [
            o.simulate_stage_multi(layer, acc, groups, batch=n).sequential_duration
            for n in (1, 2, 3)
        ]
        assert seq[2] - seq[1] == seq[1] - seq[0]


class TestFaultStreamDecorrelation:
    """Satellite: ``FaultModel.for_stage`` — stage-mixed seeds, stage 0
    stable. (The cross-language pin lives in ``test_fault_oracle.py``.)"""

    MODEL = o.FaultModel(
        seed=77, dma_fail_rate=0.4, max_retries=3, retry_penalty=5,
        dma_jitter=3, t_acc_jitter=2, shrink_rate=0.1, shrink_elements=8,
    )

    def test_stage0_is_identity(self):
        assert self.MODEL.for_stage(0) == self.MODEL

    def test_stages_draw_distinct_streams(self):
        draws = {
            self.MODEL.for_stage(i).step_faults(0, 100, 10, True).dma_jitter
            for i in range(16)
        }
        assert len(draws) > 1, "stage mixing left step-0 streams identical"

    def test_stage_mixing_is_deterministic(self):
        a = self.MODEL.for_stage(3).step_faults(5, 100, 10, True)
        b = self.MODEL.for_stage(3).step_faults(5, 100, 10, True)
        assert a == b

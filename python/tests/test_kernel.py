"""Layer-1 correctness: the Pallas kernel against the pure-jnp oracle.

This is the core build-time correctness signal: if these pass, the HLO the
Rust runtime executes computes exactly the reference GEMM/convolution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # pragma: no cover - minimal environments
    # hypothesis is optional: keep the deterministic tests runnable and skip
    # only the property-based ones.
    class _InertStrategies:
        def __getattr__(self, _name):
            return lambda *args, **kwargs: None

    st = _InertStrategies()

    def given(**_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(**_kwargs):
        return lambda fn: fn

from compile.kernels import ref, step_conv


def rand(shape, seed):
    return jax.random.uniform(
        jax.random.PRNGKey(seed), shape, dtype=jnp.float32, minval=-1.0, maxval=1.0
    )


class TestStepGemm:
    @pytest.mark.parametrize("g,d,n", [
        (1, 9, 1),
        (2, 18, 2),
        (4, 25, 6),
        (8, 150, 16),
        (5, 27, 16),   # g not divisible by tile
        (3, 7, 3),     # odd everything
    ])
    def test_matches_ref(self, g, d, n):
        patches = rand((g, d), seed=g * 100 + d)
        kmat = rand((d, n), seed=n)
        got = step_conv.step_gemm(patches, kmat)
        want = ref.step_gemm_ref(patches, kmat)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=40, deadline=None)
    @given(
        g=st.integers(min_value=1, max_value=17),
        d=st.integers(min_value=1, max_value=64),
        n=st.integers(min_value=1, max_value=20),
        tile=st.sampled_from([1, 2, 4, 8]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_shapes(self, g, d, n, tile, seed):
        patches = rand((g, d), seed=seed)
        kmat = rand((d, n), seed=seed + 1)
        got = step_conv.step_gemm(patches, kmat, tile_g=tile)
        want = ref.step_gemm_ref(patches, kmat)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_zero_padding_rows_are_dropped(self):
        # g=3 with tile 8 pads to 8; result must still be [3, n]
        patches = rand((3, 9), seed=1)
        kmat = rand((9, 2), seed=2)
        out = step_conv.step_gemm(patches, kmat, tile_g=8)
        assert out.shape == (3, 2)

    def test_dtype_f32(self):
        patches = rand((4, 9), seed=3)
        kmat = rand((9, 1), seed=4)
        assert step_conv.step_gemm(patches, kmat).dtype == jnp.float32

    def test_bf16_inputs_accumulate_f32(self):
        # MXU-style usage: bf16 operands with f32 accumulation stays close
        # to the f32 oracle for small D.
        patches = rand((4, 9), seed=5).astype(jnp.bfloat16)
        kmat = rand((9, 2), seed=6).astype(jnp.bfloat16)
        got = step_conv.step_gemm(
            patches.astype(jnp.float32), kmat.astype(jnp.float32)
        )
        want = ref.step_gemm_ref(
            patches.astype(jnp.float32), kmat.astype(jnp.float32)
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestIm2colRefInternal:
    """Shape/layout checks for the reference im2col itself."""

    def test_rows_are_row_major_patches(self):
        inp = jnp.arange(2 * 4 * 4, dtype=jnp.float32).reshape(2, 4, 4)
        cols = ref.im2col_ref(inp, 3, 3)
        assert cols.shape == (4, 18)
        # patch (0,0), channel-major: channel 0 window then channel 1 window
        first = inp[0, :3, :3].reshape(-1)
        second = inp[1, :3, :3].reshape(-1)
        np.testing.assert_array_equal(cols[0], jnp.concatenate([first, second]))

    def test_strided(self):
        inp = rand((1, 7, 7), seed=9)
        cols = ref.im2col_ref(inp, 3, 3, s_h=2, s_w=2)
        assert cols.shape == (9, 9)


class TestConvIm2col:
    @pytest.mark.parametrize("c_in,h_in,w_in,n,k,s", [
        (1, 6, 6, 1, 3, 1),
        (2, 5, 5, 2, 3, 1),
        (1, 32, 32, 6, 5, 1),   # LeNet conv1
        (3, 9, 9, 4, 3, 2),     # strided
        (6, 14, 14, 16, 5, 1),  # LeNet conv2
    ])
    def test_matches_lax_conv(self, c_in, h_in, w_in, n, k, s):
        inp = rand((c_in, h_in, w_in), seed=c_in + h_in)
        kernels = rand((n, c_in, k, k), seed=n + k)
        got = step_conv.conv2d_im2col(inp, kernels, h_k=k, w_k=k, s_h=s, s_w=s)
        want = ref.conv2d_ref(inp, kernels, s_h=s, s_w=s)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        c_in=st.integers(min_value=1, max_value=4),
        h_extra=st.integers(min_value=0, max_value=6),
        n=st.integers(min_value=1, max_value=8),
        k=st.sampled_from([1, 3, 5]),
        s=st.sampled_from([1, 2]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_layers(self, c_in, h_extra, n, k, s, seed):
        h_in = k + h_extra  # always >= kernel
        inp = rand((c_in, h_in, h_in), seed=seed)
        kernels = rand((n, c_in, k, k), seed=seed + 1)
        got = step_conv.conv2d_im2col(inp, kernels, h_k=k, w_k=k, s_h=s, s_w=s)
        want = ref.conv2d_ref(inp, kernels, s_h=s, s_w=s)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_ref_matches_definition8_shapes(self):
        inp = rand((2, 10, 8), seed=1)
        kernels = rand((3, 2, 3, 3), seed=2)
        out = ref.conv2d_ref(inp, kernels, s_h=2, s_w=1)
        assert out.shape == (3, 4, 6)

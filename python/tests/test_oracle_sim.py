"""Unit tests for the independent Python oracle (`oracle_sim`).

The oracle must stand on its own: these tests pin it against hand-computed
values and against the published Rust planner baselines (EXPERIMENTS.md) —
if the oracle reproduces lenet5's 7100 cycles and resnet8's 27644 cycles
from nothing but the paper's definitions, the differential comparison in
``test_differential.py`` is meaningful.
"""

import oracle_sim as o


class TestLayerGeometry:
    def test_dense_output_dims(self):
        l = o.Layer(2, 5, 5, 3, 3, 2)
        assert (l.h_out, l.w_out, l.n_patches) == (3, 3, 9)
        assert l.kernel_elements == 2 * 2 * 9

    def test_dilated_span_and_dims(self):
        l = o.Layer(1, 9, 9, 3, 3, 1, d_h=2, d_w=2)
        assert (l.h_span, l.w_span) == (5, 5)
        assert (l.h_out, l.w_out) == (5, 5)

    def test_dilated_patch_is_a_lattice(self):
        l = o.Layer(1, 9, 9, 3, 3, 1, d_h=2, d_w=2)
        px = l.patch_pixels(0)
        assert px == {h * 9 + w for h in (0, 2, 4) for w in (0, 2, 4)}
        # dilation holes: adjacent patches are disjoint at odd offsets
        assert not (l.patch_pixels(0) & l.patch_pixels(1))
        assert len(l.patch_pixels(0) & l.patch_pixels(2)) == 6

    def test_grouped_kernel_storage(self):
        l = o.Layer(4, 6, 6, 3, 3, 8, groups=4)
        assert l.kernel_dims_len == 9
        assert l.kernel_elements == 72


class TestStageSimulation:
    def test_single_row_scan_accounting(self):
        # 1x3x12 input, 3x3 kernel -> a single row of 10 patches; groups of
        # 2 scan left to right, so every cost is hand-computable:
        # step 1 loads the 3x4 window of its 2 patches (12 px) + the 9
        # kernel elements; steps 2..5 each slide 2 columns (6 px) and write
        # back the previous group (2 patches x 1 ch); the flush writes the
        # last group.
        l = o.Layer(1, 3, 12, 3, 3, 1)
        assert (l.h_out, l.w_out) == (1, 10)
        acc = o.Accelerator(nbop_pe=18, t_acc=1, size_mem=10_000, t_l=1, t_w=1)
        groups = o.order_to_groups(o.row_major_order(l), 2)
        r = o.simulate_stage(l, acc, groups)
        assert r.loaded_pixels == 12 + 4 * 6
        assert r.loaded_elements == (12 + 9) + 4 * 6
        # durations: (12+9)+1 | 4 x (6 load + 2 write + 1) | flush 2 writes
        assert r.duration == 22 + 4 * 9 + 2
        assert r.n_steps == 6

    def test_duplicate_patch_rejected(self):
        l = o.Layer(1, 4, 4, 3, 3, 1)
        acc = o.Accelerator(9, 1, 1000, 1, 0)
        try:
            o.simulate_stage(l, acc, [[0, 1], [1, 2, 3]])
        except AssertionError:
            pass
        else:
            raise AssertionError("duplicate patch must be rejected")

    def test_missing_patch_rejected(self):
        l = o.Layer(1, 4, 4, 3, 3, 1)
        acc = o.Accelerator(9, 1, 1000, 1, 0)
        try:
            o.simulate_stage(l, acc, [[0, 1]])
        except AssertionError:
            pass
        else:
            raise AssertionError("missing patches must be rejected")

    def test_loads_bounded_below_by_distinct_pixels(self):
        l = o.Layer(1, 8, 8, 3, 3, 1, d_h=2, d_w=2)
        groups = o.order_to_groups(o.zigzag_order(l), 3)
        distinct = set()
        for p in range(l.n_patches):
            distinct |= l.patch_pixels(p)
        acc = o.Accelerator(1000, 1, 100000, 1, 0)
        r = o.simulate_stage(l, acc, groups)
        assert r.loaded_pixels >= len(distinct)


class TestPlannerBaselines:
    """The oracle must reproduce the Rust planner's analytic (anneal-free)
    baselines recorded in EXPERIMENTS.md, from an independent code base."""

    @staticmethod
    def _stage_duration(layer, loaded_px, k):
        # for_group_size machines: t_l = t_acc = 1, t_w = 0.
        return loaded_px * layer.c_in + layer.kernel_elements + k

    def _check(self, layers, want_px, want_winners, want_total, group=4):
        total = 0
        for layer, px, winner in zip(layers, want_px, want_winners):
            got_winner, got_px, _ = o.analytic_portfolio(layer, group)
            assert got_px == px, f"{layer}: {got_px} != {px}"
            assert got_winner == winner
            k = -(-layer.n_patches // group)
            total += self._stage_duration(layer, got_px, k)
        assert total == want_total

    def test_lenet5(self):
        self._check(
            [o.Layer(1, 32, 32, 5, 5, 6), o.Layer(6, 14, 14, 5, 5, 16)],
            [2385, 324],
            ["greedy", "hilbert"],
            7100,
        )

    def test_resnet8(self):
        conv2 = o.Layer(16, 18, 18, 3, 3, 16)
        self._check(
            [o.Layer(3, 34, 34, 3, 3, 16), conv2, conv2],
            [1988, 508, 508],
            ["greedy", "greedy", "greedy"],
            27644,
        )

    def test_mobilenet_slim(self):
        # The generalized-zoo baseline added by this PR (EXPERIMENTS.md):
        # depthwise 3x3 s2 -> pointwise 1x1 -> dilated 3x3 (d=2).
        self._check(
            [
                o.Layer(4, 18, 18, 3, 3, 4, s_h=2, s_w=2, groups=4),
                o.Layer(4, 8, 8, 1, 1, 8),
                o.Layer(8, 12, 12, 3, 3, 8, d_h=2, d_w=2),
            ],
            [325, 64, 165],
            ["hilbert", "row-by-row", "greedy"],
            3568,
        )


class TestOverlappedTimeline:
    """The §3.7 double-buffered timeline — the same hand-computed 3-step
    example the Rust side pins in
    ``sim::engine::tests::double_buffered_hand_computed_makespan``."""

    def _setup(self):
        l = o.Layer(1, 3, 12, 3, 3, 1)
        groups = o.order_to_groups(o.row_major_order(l), 4)
        return l, groups

    def test_hand_computed_roomy_makespan(self):
        # Steps load (18+9 kernel, 12, 6) elements, write (0, 4, 4) + flush
        # 2 at t_w = 1, t_acc = 4; sequential = 31 + 20 + 14 + 2 = 67.
        # With size_mem = 64 every load prefetches: the makespan is
        # DMA-bound at 55 cycles — all 12 compute cycles hidden.
        l, groups = self._setup()
        acc = o.Accelerator(nbop_pe=36, t_acc=4, size_mem=64, t_l=1, t_w=1)
        seq = o.simulate_stage(l, acc, groups)
        assert seq.duration == 67
        r = o.simulate_stage_overlapped(l, acc, groups)
        assert r.sequential_duration == 67
        assert r.makespan == 55
        assert r.dma_busy == 55
        assert r.compute_busy == 12
        assert r.n_prefetched == 2

    def test_hand_computed_serialization_fallback(self):
        # size_mem = 40: step 2's 12 incoming elements do not fit beside
        # step 1's 31-element working set -> its load serializes behind
        # compute 1; makespan 59, still <= sequential.
        l, groups = self._setup()
        acc = o.Accelerator(nbop_pe=36, t_acc=4, size_mem=40, t_l=1, t_w=1)
        r = o.simulate_stage_overlapped(l, acc, groups)
        assert r.makespan == 59
        assert r.n_prefetched == 1

    def test_bounds_hold_across_orderings(self):
        for l in [
            o.Layer(2, 5, 5, 3, 3, 2),
            o.Layer(1, 8, 8, 3, 3, 1, d_h=2, d_w=2),
            o.Layer(4, 7, 7, 3, 3, 4, groups=4),
        ]:
            acc = o.for_group_size(l, 3)
            for name, order_fn in o.ORDERINGS.items():
                groups = o.order_to_groups(order_fn(l), 3)
                seq = o.simulate_stage(l, acc, groups)
                r = o.simulate_stage_overlapped(l, acc, groups)
                assert r.sequential_duration == seq.duration, name
                assert r.makespan <= seq.duration, name
                assert r.makespan >= max(r.dma_busy, r.compute_busy), name


class TestOverlappedPlannerBaselines:
    """The double-buffered analytic baselines pinned (as upper bounds) by
    ``rust/tests/integration_planner.rs::
    double_buffered_planner_never_regresses_the_overlap_baseline`` —
    reproduced here exactly, from the independent code base."""

    def _check(self, layers, want_makespans, want_winners, want_total, group=4):
        total = 0
        for layer, makespan, winner in zip(layers, want_makespans, want_winners):
            got_winner, got_makespan, _ = o.analytic_portfolio_overlapped(layer, group)
            assert got_makespan == makespan, f"{layer}: {got_makespan} != {makespan}"
            assert got_winner == winner
            total += got_makespan
        assert total == want_total

    def test_lenet5(self):
        self._check(
            [o.Layer(1, 32, 32, 5, 5, 6), o.Layer(6, 14, 14, 5, 5, 16)],
            [2538, 4345],
            ["greedy", "hilbert"],
            6883,
        )

    def test_resnet8(self):
        conv2 = o.Layer(16, 18, 18, 3, 3, 16)
        self._check(
            [o.Layer(3, 34, 34, 3, 3, 16), conv2, conv2],
            [6402, 10435, 10435],
            ["greedy", "greedy", "greedy"],
            27272,
        )

    def test_mobilenet_slim(self):
        self._check(
            [
                o.Layer(4, 18, 18, 3, 3, 4, s_h=2, s_w=2, groups=4),
                o.Layer(4, 8, 8, 1, 1, 8),
                o.Layer(8, 12, 12, 3, 3, 8, d_h=2, d_w=2),
            ],
            [1352, 304, 1898],
            ["hilbert", "row-by-row", "greedy"],
            3554,
        )

    def test_overlapped_never_exceeds_sequential_baseline(self):
        # Totals vs the sequential baselines 7100 / 27644 / 3568.
        for layers, seq_total in [
            ([o.Layer(1, 32, 32, 5, 5, 6), o.Layer(6, 14, 14, 5, 5, 16)], 7100),
            (
                [
                    o.Layer(3, 34, 34, 3, 3, 16),
                    o.Layer(16, 18, 18, 3, 3, 16),
                    o.Layer(16, 18, 18, 3, 3, 16),
                ],
                27644,
            ),
        ]:
            total = sum(
                o.analytic_portfolio_overlapped(l, 4)[1] for l in layers
            )
            assert total <= seq_total


class TestNetworkChaining:
    def test_pool_and_pad_dims(self):
        l = o.Layer(1, 32, 32, 5, 5, 6)
        assert o.next_stage_dims(l, True, 0) == (6, 14, 14)
        assert o.next_stage_dims(l, False, 1) == (6, 30, 30)


class TestBatchPlanner:
    """The batch planner's cross-network dedup accounting, reproduced from
    the independent code base via the ``CacheKey`` v4 mirror. Pins the Rust
    acceptance batch ``[lenet5, lenet5, resnet8, mobilenet_slim]``:
    10 stages -> 7 unique planning problems, 3 dedup hits of which 2 are
    cross-network (``rust/tests/integration_batch.rs``)."""

    @staticmethod
    def _zoo():
        lenet5 = [o.Layer(1, 32, 32, 5, 5, 6), o.Layer(6, 14, 14, 5, 5, 16)]
        conv2 = o.Layer(16, 18, 18, 3, 3, 16)
        resnet8 = [o.Layer(3, 34, 34, 3, 3, 16), conv2, conv2]
        mobilenet_slim = [
            o.Layer(4, 18, 18, 3, 3, 4, s_h=2, s_w=2, groups=4),
            o.Layer(4, 8, 8, 1, 1, 8),
            o.Layer(8, 12, 12, 3, 3, 8, d_h=2, d_w=2),
        ]
        return [lenet5, lenet5, resnet8, mobilenet_slim]

    def test_zoo_batch_dedup_accounting(self):
        for overlap in ("sequential", "double-buffered"):
            stats = o.batch_dedup(self._zoo(), 4, overlap=overlap)
            assert stats == {
                "stages_total": 10,
                "unique_problems": 7,
                "dedup_hits": 3,
                "cross_network_dedup_hits": 2,
            }, overlap

    def test_key_covers_geometry_platform_and_mode(self):
        layer = o.Layer(4, 12, 12, 3, 3, 4)
        acc = o.for_group_size(layer, 4)
        k = -(-layer.n_patches // 4)
        base = o.cache_key(layer, acc, 4, k, 2026, 50_000, 3)
        assert base.startswith("v4|") and "|ovl:sequential|" in base
        assert "|ch:1x1|" in base
        # overlap mode is part of the planning problem
        db = o.Accelerator(acc.nbop_pe, acc.t_acc, acc.size_mem, acc.t_l,
                           acc.t_w, overlap="double-buffered")
        assert o.cache_key(layer, db, 4, k, 2026, 50_000, 3) != base
        # so is the resource shape (k DMA channels x m compute units)
        from dataclasses import replace
        wide = replace(acc, dma_channels=2, compute_units=3)
        assert o.cache_key(layer, wide, 4, k, 2026, 50_000, 3) != base
        # dilation and channel groups are layer geometry
        dil = o.Layer(4, 12, 12, 3, 3, 4, d_h=2, d_w=2)
        grp = o.Layer(4, 12, 12, 3, 3, 4, groups=4)
        assert o.cache_key(dil, acc, 4, k, 2026, 50_000, 3) != base
        assert o.cache_key(grp, acc, 4, k, 2026, 50_000, 3) != base
        # and so is the portfolio configuration
        assert o.cache_key(layer, acc, 4, k, 2027, 50_000, 3) != base

    def test_dedup_counts_repeats_within_one_network(self):
        conv2 = o.Layer(16, 18, 18, 3, 3, 16)
        stats = o.batch_dedup([[conv2, conv2, conv2]], 4)
        assert stats["unique_problems"] == 1
        assert stats["dedup_hits"] == 2
        assert stats["cross_network_dedup_hits"] == 0

    def test_different_group_bounds_never_dedupe(self):
        layer = o.Layer(1, 8, 8, 3, 3, 1)
        a = o.batch_dedup([[layer], [layer]], 2)
        assert a["cross_network_dedup_hits"] == 1
        keys = set()
        for g in (2, 4):
            acc = o.for_group_size(layer, g)
            k = -(-layer.n_patches // g)
            keys.add(o.cache_key(layer, acc, g, k, 2026, 50_000, 3))
        assert len(keys) == 2

"""Layer-2 checks: model graph shapes and AOT lowering round-trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def rand(shape, seed):
    return jax.random.uniform(
        jax.random.PRNGKey(seed), shape, dtype=jnp.float32, minval=-1.0, maxval=1.0
    )


class TestStepComputeFn:
    def test_shapes_and_values(self):
        fn, args = model.step_compute_fn(g_max=8, d=18, n=2)
        patches = rand((8, 18), seed=1)
        kmat = rand((18, 2), seed=2)
        (out,) = fn(patches, kmat)
        assert out.shape == (8, 2)
        np.testing.assert_allclose(
            out, ref.step_gemm_ref(patches, kmat), rtol=1e-5, atol=1e-5
        )
        assert [a.shape for a in args] == [(8, 18), (18, 2)]

    def test_padded_rows_pass_through_as_zero(self):
        # the coordinator pads groups with zero rows; their outputs are zero
        fn, _ = model.step_compute_fn(g_max=4, d=9, n=3)
        patches = jnp.zeros((4, 9), dtype=jnp.float32).at[0].set(1.0)
        kmat = rand((9, 3), seed=3)
        (out,) = fn(patches, kmat)
        np.testing.assert_allclose(out[1:], np.zeros((3, 3)), atol=1e-7)


class TestLayerForwardFn:
    def test_matches_lax_conv(self):
        fn, args = model.layer_forward_fn(2, 5, 5, 2, 3, 3)
        inp = rand((2, 5, 5), seed=4)
        kernels = rand((2, 2, 3, 3), seed=5)
        (out,) = fn(inp, kernels)
        np.testing.assert_allclose(
            out, ref.conv2d_ref(inp, kernels), rtol=1e-4, atol=1e-4
        )
        assert [a.shape for a in args] == [(2, 5, 5), (2, 2, 3, 3)]


class TestAotLowering:
    @pytest.mark.parametrize("variant", aot.STEP_VARIANTS[:3])
    def test_step_variants_lower_to_hlo_text(self, variant):
        fn, args = model.step_compute_fn(
            variant["g_max"], variant["d"], variant["n"]
        )
        text = aot.to_hlo_text(fn, args)
        assert "HloModule" in text
        # static shapes present in the module signature
        assert f"f32[{variant['g_max']},{variant['d']}]" in text

    def test_layer_variant_lowers(self):
        v = aot.LAYER_VARIANTS[2]  # example1 (small)
        fn, args = model.layer_forward_fn(
            v["c_in"], v["h_in"], v["w_in"], v["n"], v["h_k"], v["w_k"]
        )
        text = aot.to_hlo_text(fn, args)
        assert "HloModule" in text

    def test_lowered_hlo_contains_single_fused_dot(self):
        # §Perf L2 target: the step compute lowers to one dot per tile, no
        # redundant transposes of the kernel operand.
        fn, args = model.step_compute_fn(8, 9, 1)
        text = aot.to_hlo_text(fn, args)
        assert text.count("dot(") >= 1

    def test_build_all_writes_manifest(self, tmp_path, monkeypatch):
        # Build only the two smallest variants to keep the test quick.
        monkeypatch.setattr(aot, "STEP_VARIANTS", aot.STEP_VARIANTS[:1])
        monkeypatch.setattr(aot, "LAYER_VARIANTS", aot.LAYER_VARIANTS[2:])
        aot.build_all(str(tmp_path))
        manifest = (tmp_path / "manifest.json").read_text()
        import json

        m = json.loads(manifest)
        assert len(m["step"]) == 1
        assert len(m["layer"]) == 1
        for entry in m["step"] + m["layer"]:
            assert (tmp_path / entry["file"]).exists()

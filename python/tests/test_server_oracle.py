"""Pins for the plan-server's pure decision logic, mirrored from Rust.

The server's load-shedding ladder (``server::admission::select_rung`` /
``rung_budgets``), its journal-replay semantics
(``server::journal::replay_lines``) and the retry backoff schedule
(``planner::recovery::backoff_schedule``) are pure functions on both sides
of the language boundary.  This file pins the *same decision tables* as the
Rust unit tests (``rung_decision_table_is_pinned``,
``rung_budgets_are_pinned``, the ``journal.rs`` replay tests and
``backoff_schedule_is_pinned_per_seed``), so a drift in either
implementation fails one suite even without a Rust toolchain present.
"""

import json

import pytest

import oracle_sim as o


# ------------------------------------------------------- degradation ladder


def test_rung_decision_table_is_pinned():
    # queue pressure alone (no deadline) — same table as admission.rs
    assert o.select_rung(0, 16, None) == "full"
    assert o.select_rung(1, 16, None) == "reduced"
    assert o.select_rung(8, 16, None) == "reduced"
    assert o.select_rung(9, 16, None) == "heuristic"
    assert o.select_rung(15, 16, None) == "heuristic"
    assert o.select_rung(16, 16, None) == "cache-only"
    assert o.select_rung(40, 16, None) == "cache-only"
    # budget pressure alone (idle queue)
    assert o.select_rung(0, 16, 5_000) == "full"
    assert o.select_rung(0, 16, 1_000) == "full"
    assert o.select_rung(0, 16, 999) == "reduced"
    assert o.select_rung(0, 16, 100) == "reduced"
    assert o.select_rung(0, 16, 99) == "heuristic"
    assert o.select_rung(0, 16, 10) == "heuristic"
    assert o.select_rung(0, 16, 9) == "cache-only"
    assert o.select_rung(0, 16, 0) == "cache-only"
    # combination: the more degraded signal wins
    assert o.select_rung(8, 16, 5) == "cache-only"
    assert o.select_rung(16, 16, 5_000) == "cache-only"
    assert o.select_rung(1, 16, 50) == "heuristic"
    # tiny capacity: any backlog is already at capacity
    assert o.select_rung(1, 1, None) == "cache-only"


def test_rung_is_monotone_in_both_pressure_signals():
    """More backlog or less budget never *increases* effort."""
    budgets = [None, 5_000, 999, 100, 50, 10, 5, 0]
    for cap in (1, 2, 16):
        for b in budgets:
            rungs = [o.select_rung(d, cap, b) for d in range(0, cap + 3)]
            idx = [o.RUNGS.index(r) for r in rungs]
            assert idx == sorted(idx), (cap, b, rungs)
    for depth in (0, 1, 8, 16):
        idx = [
            o.RUNGS.index(o.select_rung(depth, 16, b))
            for b in [None, 5_000, 999, 100, 50, 10, 5, 0]
        ]
        assert idx == sorted(idx), (depth, idx)


def test_rung_budgets_are_pinned():
    assert o.rung_budgets("full", 3, 50_000) == (3, 50_000)
    assert o.rung_budgets("reduced", 3, 50_000) == (1, 12_500)
    assert o.rung_budgets("heuristic", 3, 50_000) == (0, 0)
    assert o.rung_budgets("cache-only", 3, 50_000) is None
    with pytest.raises(ValueError):
        o.rung_budgets("turbo", 3, 50_000)


# ----------------------------------------------------------- journal replay


def recv(rec_id, req=None):
    body = {"v": 1, "e": "recv", "id": rec_id, "req": req or {"op": "plan"}}
    return json.dumps(body, sort_keys=True, separators=(",", ":"))


def done(rec_id):
    body = {"v": 1, "e": "done", "id": rec_id}
    return json.dumps(body, sort_keys=True, separators=(",", ":"))


def test_replay_pairs_recv_with_done():
    r = o.journal_replay([recv(0), recv(1), done(0)])
    assert r["pending"] == [(1, {"op": "plan"})]
    assert not r["torn_tail"]
    assert r["next_id"] == 2


def test_replay_of_empty_and_blank_journals():
    assert o.journal_replay([]) == {
        "pending": [],
        "torn_tail": False,
        "next_id": 0,
    }
    r = o.journal_replay(["", "   ", recv(3), ""])
    assert r["pending"] == [(3, {"op": "plan"})]
    assert r["next_id"] == 4


def test_torn_tail_is_dropped_but_interior_corruption_raises():
    # a crash mid-append: the malformed *last* line is dropped and flagged
    r = o.journal_replay([recv(3), '{"v":1,"e":"recv","id":4,"req":{"op"'])
    assert r["torn_tail"]
    assert r["pending"] == [(3, {"op": "plan"})]
    assert r["next_id"] == 4

    with pytest.raises(ValueError, match="line 1"):
        o.journal_replay(["garbage", recv(3)])
    with pytest.raises(ValueError, match="duplicate"):
        o.journal_replay([recv(5), recv(5), done(9)])

    # a done whose recv was compacted away is harmless
    r = o.journal_replay([done(7)])
    assert r["pending"] == []
    assert r["next_id"] == 8


def test_replay_rejects_bad_versions_and_ids_strictly():
    # wrong version, missing id, negative id, fractional id, bool id — all
    # malformed; interior position makes each fatal
    bad = [
        '{"v":2,"e":"done","id":0}',
        '{"v":1,"e":"done"}',
        '{"v":1,"e":"done","id":-1}',
        '{"v":1,"e":"done","id":1.5}',
        '{"v":1,"e":"done","id":true}',
        '{"v":1,"e":"boom","id":0}',
        '{"v":1,"e":"recv","id":0}',
        '{"v":1,"e":"recv","id":0,"req":[1]}',
        "[1,2,3]",
    ]
    for line in bad:
        with pytest.raises(ValueError, match="line 1"):
            o.journal_replay([line, recv(3)])
        # the same malformation in last position is a tolerated torn tail
        r = o.journal_replay([recv(3), line])
        assert r["torn_tail"] and r["pending"] == [(3, {"op": "plan"})]


def test_replay_preserves_receive_order():
    lines = [recv(i, {"op": "plan", "n": i}) for i in range(5)]
    lines.append(done(2))
    r = o.journal_replay(lines)
    assert [p for p, _ in r["pending"]] == [0, 1, 3, 4]
    assert r["next_id"] == 5


# --------------------------------------------------------- backoff schedule


def test_backoff_schedule_matches_the_rust_pins():
    # identical to planner/recovery.rs backoff_schedule_is_pinned_per_seed
    assert o.backoff_schedule(4, 2000, 42) == [2167, 5516, 13441]
    assert o.backoff_schedule(3, 500, 7) == [850, 1279]
    for i, d in enumerate(o.backoff_schedule(6, 100, 99)):
        lo = 100 * (1 << i)
        assert lo <= d <= 2 * lo
    assert o.backoff_schedule(4, 2000, 1) != o.backoff_schedule(4, 2000, 2)
    assert o.backoff_schedule(1, 2000, 42) == []
    assert o.backoff_schedule(0, 2000, 42) == []
    assert o.backoff_schedule(4, 0, 42) == [0, 0, 0]

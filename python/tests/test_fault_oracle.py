"""Unit tests for the oracle's fault-injection mirror.

The fault subsystem is a cross-language contract: the Rust side pins the
same RNG constants (`rust/src/platform/fault.rs`,
``rng_cross_language_pins``), so if both language's generators agree on
these values and both sides follow the documented per-step draw order, the
differential gate (`test_differential.py`) compares like with like. The
rest of the file checks the oracle's own fault properties — zero-fault
bit-identity, stream determinism, WCET dominance — independently of any
Rust artifact, so a Python-only dev loop still exercises the model.
"""

import oracle_sim as o

M64 = (1 << 64) - 1


def storm(seed):
    """A model with every fault axis live (the differential harness's)."""
    return o.FaultModel(
        seed=seed,
        dma_fail_rate=0.35,
        max_retries=3,
        retry_penalty=9,
        dma_jitter=4,
        t_acc_jitter=3,
        shrink_rate=0.15,
        shrink_elements=32,
    )


def sample_problems():
    """A small zoo of (layer, accelerator, groups) triples covering dense,
    strided/dilated and grouped layers under several orderings."""
    problems = []
    for layer, g in (
        (o.Layer(1, 8, 8, 3, 3, 1), 2),
        (o.Layer(2, 10, 10, 3, 3, 4, s_h=2, s_w=2), 3),
        (o.Layer(3, 12, 12, 3, 3, 3, d_h=2, d_w=2, groups=3), 4),
        (o.Layer(4, 9, 9, 2, 2, 8, groups=2), 5),
    ):
        for name in ("row-by-row", "zigzag", "greedy"):
            if name == "greedy":
                k = -(-layer.n_patches // g)
                groups = o.greedy_groups(layer, k)
            else:
                groups = o.order_to_groups(o.ORDERINGS[name](layer), g)
            acc = o.for_group_size(layer, g)
            acc.t_acc = 3
            acc.t_w = 1
            problems.append((layer, acc, groups))
    return problems


class TestRngCrossLanguagePins:
    """Bit-identical to `util::rng::Rng` — same constants as the Rust test."""

    def test_next_u64_stream(self):
        r = o.Rng(42)
        assert [r.next_u64() for _ in range(5)] == [
            1546998764402558742,
            6990951692964543102,
            12544586762248559009,
            17057574109182124193,
            18295552978065317476,
        ]

    def test_zero_seed_stream(self):
        r = o.Rng(0)
        assert [r.next_u64() for _ in range(3)] == [
            11091344671253066420,
            13793997310169335082,
            1900383378846508768,
        ]

    def test_lemire_below(self):
        r = o.Rng(7)
        assert [r.below(100) for _ in range(8)] == [70, 27, 83, 98, 99, 87, 6, 10]

    def test_bernoulli_chance(self):
        r = o.Rng(2026)
        got = [r.chance(0.3) for _ in range(12)]
        want = [False, True] + [False] * 7 + [True, False, False]
        assert got == want

    def test_per_step_stream_seeds(self):
        """The stateless per-step streams (`seed ^ index * GOLDEN`) used by
        `FaultModel.step_faults` — pinned for steps 0, 1 and 5 of seed 13."""
        for index, want in (
            (0, [4469561385778016610, 14440143515961338743]),
            (1, [13543073186684114632, 8432558809597263448]),
            (5, [7099007645392894103, 7628968799164756082]),
        ):
            r = o.Rng(13 ^ ((index * o.GOLDEN) & M64))
            assert [r.next_u64() for _ in range(2)] == want, f"step {index}"


class TestStageDecorrelation:
    """`FaultModel.for_stage` — the stage index golden-ratio-*added* into
    the seed (the per-step spreading xors, so the two mixes cannot cancel),
    pinned to the same values as `platform::fault::stage_seed_mixing_pins`
    on the Rust side."""

    def test_stage_seed_pins(self):
        m = storm(13)
        assert [m.for_stage(i).seed for i in range(4)] == [
            13,
            11400714819323198498,
            4354685564936845367,
            15755400384260043852,
        ]

    def test_stage0_keeps_single_stage_traces_stable(self):
        m = storm(13)
        assert m.for_stage(0) == m
        for layer, acc, groups in sample_problems()[:3]:
            a = o.simulate_stage_faulted(layer, acc, groups, m)
            b = o.simulate_stage_faulted(layer, acc, groups, m.for_stage(0))
            assert a == b

    def test_stages_no_longer_share_step0_draws(self):
        m = storm(13)
        step0 = [
            m.for_stage(i).step_faults(0, 500, 50, True) for i in range(8)
        ]
        assert len({(f.load_retries, f.dma_jitter, f.compute_jitter, f.shrink)
                    for f in step0}) > 1


class TestZeroFaultIdentity:
    def test_inactive_model_is_bit_identical_sequentially(self):
        inert = o.FaultModel(seed=99)
        assert not inert.is_active()
        for layer, acc, groups in sample_problems():
            clean = o.simulate_stage(layer, acc, groups)
            faulted = o.simulate_stage_faulted(layer, acc, groups, inert)
            assert faulted.duration == clean.duration
            assert faulted.fault_retries == 0
            assert faulted.mem_shrink_events == 0
            assert faulted.n_steps == clean.n_steps
            # With nothing injected the bound collapses onto the clean sum.
            assert faulted.wcet_bound == clean.duration

    def test_inactive_model_is_bit_identical_overlapped(self):
        inert = o.FaultModel(seed=31)
        for layer, acc, groups in sample_problems():
            clean = o.simulate_stage_overlapped(layer, acc, groups)
            faulted = o.simulate_stage_overlapped_faulted(layer, acc, groups, inert)
            assert faulted.makespan == clean.makespan
            assert faulted.sequential_duration == clean.sequential_duration
            assert faulted.dma_busy == clean.dma_busy
            assert faulted.compute_busy == clean.compute_busy

    def test_zero_rate_axes_draw_nothing(self):
        """Gating: a retries-only model must consume no draws on a step that
        loads nothing, keeping the stream stable across step shapes."""
        m = o.FaultModel(seed=5, dma_fail_rate=0.9, max_retries=3)
        fx = m.step_faults(0, 0, 128, False)  # flush: writes only
        assert (fx.load_retries, fx.dma_jitter, fx.compute_jitter) == (0, 0, 0)
        fx = m.step_faults(0, 64, 0, True)
        assert fx.load_retries > 0  # rate 0.9: first draw almost surely fails


class TestFaultDeterminism:
    def test_same_seed_same_trace(self):
        for layer, acc, groups in sample_problems():
            a = o.simulate_stage_faulted(layer, acc, groups, storm(13))
            b = o.simulate_stage_faulted(layer, acc, groups, storm(13))
            assert a == b
            x = o.simulate_stage_overlapped_faulted(layer, acc, groups, storm(13))
            y = o.simulate_stage_overlapped_faulted(layer, acc, groups, storm(13))
            assert x == y

    def test_distinct_seeds_vary_the_trace(self):
        varied = False
        for layer, acc, groups in sample_problems():
            a = o.simulate_stage_faulted(layer, acc, groups, storm(1))
            b = o.simulate_stage_faulted(layer, acc, groups, storm(2))
            varied |= a.duration != b.duration
        assert varied, "distinct fault seeds never changed any trace"

    def test_retry_stream_is_mode_agnostic(self):
        """Retries and shrinks depend on step shapes only, so the sequential
        and overlapped replays of one strategy draw identical streams."""
        for layer, acc, groups in sample_problems():
            seq = o.simulate_stage_faulted(layer, acc, groups, storm(77))
            ovl = o.simulate_stage_overlapped_faulted(layer, acc, groups, storm(77))
            assert seq.fault_retries == ovl.fault_retries
            assert seq.mem_shrink_events == ovl.mem_shrink_events
            assert ovl.sequential_duration == seq.duration
            assert ovl.makespan <= seq.duration


class TestWcetBound:
    def test_monotone_in_k(self):
        m = storm(0)
        prev = 0
        for k in range(64):
            w = m.makespan_under_k_faults(10_000, 50, 40, 120, k)
            assert w >= prev
            prev = w

    def test_dominates_hundreds_of_simulated_traces(self):
        traces = 0
        for layer, acc, groups in sample_problems():
            for fault_seed in range(10):
                m = storm(fault_seed * 1_000 + 17)
                seq = o.simulate_stage_faulted(layer, acc, groups, m)
                assert seq.wcet_bound >= seq.duration
                ovl = o.simulate_stage_overlapped_faulted(layer, acc, groups, m)
                assert ovl.wcet_bound >= ovl.makespan
                traces += 2
        assert traces >= 200, f"expected hundreds of traces, got {traces}"

    def test_bound_is_tight_at_the_caps(self):
        """Hand-computed: base 1000 cycles over 10 steps (9 compute),
        max load 40, penalty 5, jitters 3/2 — the same pin as the Rust
        `wcet_bound_is_monotone_in_k` test."""
        m = o.FaultModel(
            seed=0,
            dma_fail_rate=0.5,
            max_retries=3,
            retry_penalty=5,
            dma_jitter=3,
            t_acc_jitter=2,
        )
        assert m.makespan_under_k_faults(1000, 10, 9, 40, 0) == 1048
        assert m.makespan_under_k_faults(1000, 10, 9, 40, 2) == 1138


class TestShrinkSemantics:
    def test_shrink_only_storm_leaves_the_sequential_sum_alone(self):
        from dataclasses import replace

        m = o.FaultModel(seed=3, shrink_rate=1.0, shrink_elements=64)
        fired = stretched = 0
        for layer, acc, groups in sample_problems():
            # Roomy memory so the clean timeline genuinely prefetches and
            # the shrink has real overlap to destroy (the exact-fit
            # `for_group_size` machines mostly serialize anyway).
            acc = replace(acc, size_mem=acc.size_mem * 2)
            clean = o.simulate_stage(layer, acc, groups)
            seq = o.simulate_stage_faulted(layer, acc, groups, m)
            assert seq.duration == clean.duration
            assert seq.fault_retries == 0
            fired += seq.mem_shrink_events

            clean_ovl = o.simulate_stage_overlapped(layer, acc, groups)
            ovl = o.simulate_stage_overlapped_faulted(layer, acc, groups, m)
            assert ovl.makespan >= clean_ovl.makespan
            assert ovl.makespan <= seq.duration
            stretched += ovl.makespan - clean_ovl.makespan
        assert fired > 0, "rate-1.0 shrink storm never fired"
        assert stretched > 0, "shrink storm never forced a serialization"

    def test_shrink_is_sticky_and_applies_before_the_residency_check(self):
        """Hand-computed, on the engine's 1x3x12 example (loads 27/12/6,
        writes 4/4/2, t_acc = 4, t_w = 1; clean sequential sum 67): a
        rate-1.0 storm that shrinks the whole budget fires on step 0
        *before* step 0's own residency check, so every step — including
        the first, which would otherwise prefetch into an empty memory —
        serializes behind the previous compute. The serialized recurrence
        advances by `load + max(write, compute)` per step: 31 + 16 + 10 + 2
        = 59 cycles (the same figure as the engine's tight-memory pin,
        where size 40 also forces full serialization)."""
        layer = o.Layer(1, 3, 12, 3, 3, 1)
        acc = o.for_group_size(layer, 4)
        acc.t_acc = 4
        acc.t_w = 1
        groups = o.order_to_groups(o.row_major_order(layer), 4)
        m = o.FaultModel(seed=1, shrink_rate=1.0, shrink_elements=acc.size_mem)
        ovl = o.simulate_stage_overlapped_faulted(layer, acc, groups, m)
        assert ovl.mem_shrink_events == len(groups) + 1
        assert ovl.sequential_duration == 67
        assert ovl.makespan == 59

"""Certification oracle suite — the Python half of the cross-language gap
gate (mirrors ``rust/tests/certify.rs``).

Pins the analytic communication floor, the portfolio winner and the exact
``optimality_gap`` (IEEE-double, bit-identical across languages because both
sides divide the same two integers) for every stage of the preset zoo, and
proves both lenet5-scale micro stages optimal by brute force. These are the
CI regression pins: a gap that drifts above its recorded value fails here
even on a checkout with no Rust toolchain.
"""

import pytest

import oracle_sim as o

# The preset zoo, mirrored from ``rust/src/config/presets.rs``.
LENET5 = [
    o.Layer(1, 32, 32, 5, 5, 6),
    o.Layer(6, 14, 14, 5, 5, 16),
]
RESNET8 = [
    o.Layer(3, 34, 34, 3, 3, 16),
    o.Layer(16, 18, 18, 3, 3, 16),
    o.Layer(16, 18, 18, 3, 3, 16),
]
MOBILENET_SLIM = [
    o.Layer(4, 18, 18, 3, 3, 4, s_h=2, s_w=2, groups=4),
    o.Layer(4, 8, 8, 1, 1, 8),
    o.Layer(8, 12, 12, 3, 3, 8, d_h=2, d_w=2),
]
LENET5_MICRO = [
    o.Layer(1, 6, 6, 5, 5, 6),
    o.Layer(6, 4, 4, 3, 3, 16),
]

# Pinned certification results at the planner's default group size (4):
# (stage, bound_pixels, winner, achieved_pixels, optimality_gap). The gap
# floats are exact quotients of the pinned integers — any change to the
# bound, the portfolio, or the orderings shows up here as a regression.
ZOO_PINS = {
    "lenet5": [
        ("conv1", 1024, "greedy", 2385, 1.3291015625),
        ("conv2", 196, "hilbert", 324, 0.6530612244897959),
    ],
    "resnet8": [
        ("conv1", 1156, "greedy", 1988, 0.7197231833910035),
        ("conv2a", 324, "greedy", 508, 0.5679012345679012),
        ("conv2b", 324, "greedy", 508, 0.5679012345679012),
    ],
    "mobilenet_slim": [
        ("dw3", 289, "hilbert", 325, 0.1245674740484429),
        ("pw1", 64, "row-by-row", 64, 0.0),
        ("dil3", 144, "greedy", 165, 0.14583333333333334),
    ],
}
ZOO = {"lenet5": LENET5, "resnet8": RESNET8, "mobilenet_slim": MOBILENET_SLIM}


def test_zoo_gap_pins_hold():
    for net, layers in ZOO.items():
        for layer, (stage, bound, winner, achieved, gap) in zip(
            layers, ZOO_PINS[net]
        ):
            acc = o.for_group_size(layer, 4)
            cert = o.certify_stage(layer, acc, 4)
            assert cert["bound_pixels"] == bound, f"{net}/{stage}"
            assert cert["winner"] == winner, f"{net}/{stage}"
            assert cert["achieved_pixels"] == achieved, f"{net}/{stage}"
            # Exact float equality is intentional: the gap is a quotient of
            # the two pinned integers, deterministic on both sides.
            assert cert["optimality_gap"] == gap, f"{net}/{stage}"


def test_zoo_memory_terms_pinned():
    """The memory-dependent half of the bound, pinned so a silent change to
    the capacity model cannot hide behind a cold term that still dominates."""
    memory_pins = {
        "lenet5": [330, 0],
        "resnet8": [483, 108, 108],
        "mobilenet_slim": [108, 26, 12],
    }
    for net, layers in ZOO.items():
        for layer, mem_px in zip(layers, memory_pins[net]):
            b = o.comm_lower_bound(layer, o.for_group_size(layer, 4))
            assert b["memory_pixels"] == mem_px, f"{net}: {b['memory_pixels']}"
            assert b["bound_pixels"] == max(b["cold_pixels"], mem_px)


def test_bound_is_a_true_floor_for_every_ordering():
    """Property: the pixel floor never exceeds the loads of *any* grouped
    ordering, on every zoo layer at several group sizes."""
    for layers in ZOO.values():
        for layer in layers:
            for g in (1, 2, 4, 8):
                bound = o.comm_lower_bound(layer, o.for_group_size(layer, g))
                for name, order_fn in o.ORDERINGS.items():
                    groups = o.order_to_groups(order_fn(layer), g)
                    loads = o.grouping_loaded_pixels(layer, groups)
                    assert bound["bound_pixels"] <= loads, (
                        f"{name} g={g}: floor {bound['bound_pixels']} "
                        f"above {loads}"
                    )
                greedy = o.greedy_groups(layer, g)
                loads = o.grouping_loaded_pixels(layer, greedy)
                assert bound["bound_pixels"] <= loads


def test_bound_is_monotone_non_increasing_in_size_mem():
    for layers in ZOO.values():
        for layer in layers:
            base = o.for_group_size(layer, 4)
            prev = None
            for mem in (0, 16, 64, 256, 1024, base.size_mem, 1 << 20):
                acc = o.Accelerator(
                    nbop_pe=base.nbop_pe,
                    t_acc=base.t_acc,
                    size_mem=mem,
                    t_l=base.t_l,
                    t_w=base.t_w,
                )
                b = o.comm_lower_bound(layer, acc)["bound_pixels"]
                if prev is not None:
                    assert b <= prev, f"bound grew at size_mem={mem}"
                prev = b
            # With unbounded memory only the cold floor remains.
            assert prev == o.layer_union_pixels(layer)


def test_element_floors_follow_the_pixel_bound():
    layer = o.Layer(2, 6, 6, 3, 3, 3)
    acc = o.for_group_size(layer, 4)
    b = o.comm_lower_bound(layer, acc)
    assert b["input_element_floor"] == b["bound_pixels"] * layer.c_in
    assert b["load_element_floor"] == b["input_element_floor"] + layer.kernel_elements
    assert b["write_element_floor"] == layer.n_patches * layer.n_kernels
    assert b["min_compute_steps"] == -(-layer.n_patches // 4)


def test_optimality_gap_edge_cases():
    assert o.optimality_gap(0, 0) == 0.0
    assert o.optimality_gap(10, 0) == 0.0
    assert o.optimality_gap(10, 10) == 0.0
    assert o.optimality_gap(15, 10) == 0.5
    # A bound above the achieved value (impossible for a true floor, but the
    # function must stay total) clamps to zero rather than going negative.
    assert o.optimality_gap(5, 10) == 0.0


def test_lenet5_micro_certifies_exactly_at_group_two():
    """The acceptance pin: both micro stages are provably optimal at g=2 —
    the exact optimum equals both the analytic floor and the portfolio
    winner, so the gap is exactly zero."""
    pins = [(36, [[0, 1], [2, 3]]), (16, None)]
    for layer, (opt, want_groups) in zip(LENET5_MICRO, pins):
        assert layer.n_patches == 4
        acc = o.for_group_size(layer, 2)
        cert = o.certify_stage(layer, acc, 2)
        exact = o.exact_min_loaded_pixels(layer, 2, 2)
        assert exact is not None
        best_cost, best_groups = exact
        assert best_cost == opt
        assert cert["bound_pixels"] == opt, "the floor is tight here"
        assert cert["achieved_pixels"] == opt, "the portfolio finds it"
        assert cert["optimality_gap"] == 0.0
        if want_groups is not None:
            assert best_groups == want_groups
        # The exact groups must be a valid partition achieving the cost.
        flat = sorted(p for gr in best_groups for p in gr)
        assert flat == list(range(layer.n_patches))
        assert o.grouping_loaded_pixels(layer, best_groups) == opt


def test_exact_search_matches_enumeration_on_a_tiny_instance():
    """The pruned DFS agrees with dumb enumeration over every ordering of a
    small patch set — guards the branch-and-bound pruning logic."""
    from itertools import permutations

    layer = o.Layer(1, 4, 5, 3, 3, 2)  # 2x3 = 6 patches
    assert layer.n_patches == 6
    g, k = 2, 3
    exact = o.exact_min_loaded_pixels(layer, g, k)
    assert exact is not None
    best = None
    for perm in permutations(range(layer.n_patches)):
        groups = [list(perm[i : i + g]) for i in range(0, len(perm), g)]
        cost = o.grouping_loaded_pixels(layer, groups)
        best = cost if best is None else min(best, cost)
    assert exact[0] == best


def test_exact_search_reports_infeasible_shapes():
    layer = o.Layer(1, 4, 4, 3, 3, 2)  # 4 patches
    assert o.exact_min_loaded_pixels(layer, 1, 3) is None  # k*g < n
    assert o.exact_min_loaded_pixels(layer, 2, 5) is None  # k > n
    # Exactly-covering shapes are feasible.
    assert o.exact_min_loaded_pixels(layer, 2, 2) is not None
    assert o.exact_min_loaded_pixels(layer, 4, 1) is not None


def test_exact_optimum_is_bracketed_by_bound_and_portfolio():
    """bound <= exact <= portfolio winner, for assorted micro layers — the
    ordering that makes a certificate meaningful."""
    micro_layers = [
        o.Layer(1, 4, 4, 3, 3, 2),  # 4 patches
        o.Layer(2, 5, 4, 3, 3, 4),  # 3x2 = 6 patches
        o.Layer(1, 6, 6, 4, 4, 3, s_h=2, s_w=2),  # 2x2 = 4 patches
    ]
    for layer in micro_layers:
        g = 2
        k = -(-layer.n_patches // g)
        acc = o.for_group_size(layer, g)
        bound = o.comm_lower_bound(layer, acc)["bound_pixels"]
        exact = o.exact_min_loaded_pixels(layer, g, k)
        assert exact is not None
        winner, achieved, _ = o.analytic_portfolio(layer, g)
        assert bound <= exact[0] <= achieved, (
            f"{layer}: bound {bound}, exact {exact[0]}, achieved {achieved}"
        )


def test_cold_floor_matches_hand_computed_unions():
    # Dense 5x5 kernel, stride 1 on 32x32: every input pixel is tapped.
    assert o.layer_union_pixels(o.Layer(1, 32, 32, 5, 5, 6)) == 1024
    # Stride-2 depthwise 3x3 on 18x18: the 17x17 reachable prefix.
    assert (
        o.layer_union_pixels(
            o.Layer(4, 18, 18, 3, 3, 4, s_h=2, s_w=2, groups=4)
        )
        == 289
    )
    # Dilation-2 3x3 on 12x12: the taps cover all 144 pixels.
    assert (
        o.layer_union_pixels(o.Layer(8, 12, 12, 3, 3, 8, d_h=2, d_w=2)) == 144
    )

"""Layer-2 JAX model: the compute graphs the Rust coordinator executes.

Two graph families, both calling the Layer-1 Pallas kernel
(:mod:`compile.kernels.step_conv`) so it lowers into the same HLO:

* ``step_compute_fn`` — the accelerator's per-step action ``a_6``: one patch
  group (padded to a static ``g_max``) against all kernels. The Rust
  simulator's functional mode executes this artifact per step via PJRT.
* ``layer_forward_fn`` — the whole-layer convolution (im2col + the same
  GEMM kernel), used by the end-to-end example as the on-accelerator
  reference output.

Python runs only at build time: :mod:`compile.aot` lowers these ``jit``-ted
functions once to HLO text under ``artifacts/``.
"""

import jax
import jax.numpy as jnp

from compile.kernels import step_conv


def step_compute_fn(g_max, d, n, tile_g=8):
    """Return a jit-able fn of (patches f32[g_max, d], kernels f32[d, n]).

    The group dimension is static (= ``g_max``); the coordinator zero-pads
    smaller groups and ignores the padded rows. Returns a 1-tuple, matching
    the rust loader's ``to_tuple1`` unwrap.
    """

    def fn(patches, kernel_matrix):
        return (step_conv.step_gemm(patches, kernel_matrix, tile_g=tile_g),)

    return fn, (
        jax.ShapeDtypeStruct((g_max, d), jnp.float32),
        jax.ShapeDtypeStruct((d, n), jnp.float32),
    )


def layer_forward_fn(c_in, h_in, w_in, n, h_k, w_k, s_h=1, s_w=1, tile_g=8):
    """Return a jit-able whole-layer forward and its example arguments.

    Signature: (input f32[C_in, H_in, W_in], kernels f32[N, C_in, H_K, W_K])
    → (output f32[N, H_out, W_out],)
    """

    def fn(inp, kernels):
        return (
            step_conv.conv2d_im2col(
                inp, kernels, h_k=h_k, w_k=w_k, s_h=s_h, s_w=s_w, tile_g=tile_g
            ),
        )

    return fn, (
        jax.ShapeDtypeStruct((c_in, h_in, w_in), jnp.float32),
        jax.ShapeDtypeStruct((n, c_in, h_k, w_k), jnp.float32),
    )

"""AOT lowering: jit → StableHLO → XlaComputation → **HLO text** artifacts.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per variant plus ``manifest.json`` describing
shapes, so the Rust runtime can pick the right executable per layer config.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# Step-compute variants: one per (layer family, group capacity) used by the
# Rust examples and the figure harness. d = C_in*H_K*W_K, n = kernels.
STEP_VARIANTS = [
    # paper §7.1 sweep layers: 3x3 kernel, C_in = 1, N = 1
    {"name": "step_paper_g8", "d": 9, "n": 1, "g_max": 8},
    {"name": "step_paper_g16", "d": 9, "n": 1, "g_max": 16},
    # Example 1/2 layer: 2 channels, 3x3, two kernels
    {"name": "step_example1_g8", "d": 18, "n": 2, "g_max": 8},
    # LeNet-5 conv1: 1x5x5 kernels, 6 of them
    {"name": "step_lenet1_g8", "d": 25, "n": 6, "g_max": 8},
    # LeNet-5 conv2: 6x5x5 kernels, 16 of them
    {"name": "step_lenet2_g8", "d": 150, "n": 16, "g_max": 8},
    # ResNet-8 style: 3x3x3 kernels, 16 of them
    {"name": "step_resnet8_g8", "d": 27, "n": 16, "g_max": 8},
]

# Whole-layer forwards for the end-to-end example.
LAYER_VARIANTS = [
    {
        "name": "layer_lenet1",
        "c_in": 1, "h_in": 32, "w_in": 32, "n": 6, "h_k": 5, "w_k": 5,
        "s_h": 1, "s_w": 1,
    },
    {
        "name": "layer_lenet2",
        "c_in": 6, "h_in": 14, "w_in": 14, "n": 16, "h_k": 5, "w_k": 5,
        "s_h": 1, "s_w": 1,
    },
    {
        "name": "layer_example1",
        "c_in": 2, "h_in": 5, "w_in": 5, "n": 2, "h_k": 3, "w_k": 3,
        "s_h": 1, "s_w": 1,
    },
]


def to_hlo_text(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_all(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"step": [], "layer": []}

    for v in STEP_VARIANTS:
        fn, args = model.step_compute_fn(v["g_max"], v["d"], v["n"])
        text = to_hlo_text(fn, args)
        fname = f"{v['name']}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["step"].append({**v, "file": fname})
        print(f"wrote {fname} ({len(text)} chars)")

    for v in LAYER_VARIANTS:
        fn, args = model.layer_forward_fn(
            v["c_in"], v["h_in"], v["w_in"], v["n"], v["h_k"], v["w_k"],
            v["s_h"], v["s_w"],
        )
        text = to_hlo_text(fn, args)
        fname = f"{v['name']}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        h_out = (v["h_in"] - v["h_k"]) // v["s_h"] + 1
        w_out = (v["w_in"] - v["w_k"]) // v["s_w"] + 1
        manifest["layer"].append(
            {**v, "file": fname, "h_out": h_out, "w_out": w_out}
        )
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest.json ({len(manifest['step'])} step, "
          f"{len(manifest['layer'])} layer variants)")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    args = p.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()

"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

Everything here is straight ``jax.numpy`` / ``lax`` — no Pallas — and serves
as the ground truth the kernels are tested against (pytest + hypothesis in
``python/tests/``) and the reference the Rust oracle mirrors.
"""

import jax.numpy as jnp
from jax import lax


def step_gemm_ref(patches, kernel_matrix):
    """Reference for the per-step compute of strategy S1.

    ``patches``       — f32[G, D]  im2col rows of the step's patch group
                        (D = C_in * H_K * W_K, channel-major)
    ``kernel_matrix`` — f32[D, N]  all kernels, flattened channel-major

    Returns f32[G, N]: all output channels of every patch in the group
    (Property 1: a step computes the full C_out for its patches).
    """
    return jnp.dot(patches, kernel_matrix, preferred_element_type=jnp.float32)


def conv2d_ref(inp, kernels, s_h=1, s_w=1):
    """Whole-layer 2D convolution (cross-correlation, pre-padded input).

    ``inp``     — f32[C_in, H_in, W_in]
    ``kernels`` — f32[N, C_in, H_K, W_K]

    Returns f32[N, H_out, W_out] per Definition 8.
    """
    out = lax.conv_general_dilated(
        inp[None],  # NCHW with batch 1
        kernels,
        window_strides=(s_h, s_w),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def im2col_ref(inp, h_k, w_k, s_h=1, s_w=1):
    """im2col: f32[C_in, H_in, W_in] → f32[H_out*W_out, C_in*H_K*W_K].

    Row r = patch (i, j) with r = i * W_out + j (row-major, Remark 4);
    columns are channel-major (Remark 5), matching the Rust
    ``conv::reference::im2col_row`` layout.
    """
    c_in, h_in, w_in = inp.shape
    h_out = (h_in - h_k) // s_h + 1
    w_out = (w_in - w_k) // s_w + 1
    rows = []
    for i in range(h_out):
        for j in range(w_out):
            patch = inp[:, i * s_h : i * s_h + h_k, j * s_w : j * s_w + w_k]
            rows.append(patch.reshape(-1))
    return jnp.stack(rows)


def kernel_matrix_ref(kernels):
    """Flatten kernels f32[N, C_in, H_K, W_K] → f32[D, N] (column per kernel)."""
    n = kernels.shape[0]
    return kernels.reshape(n, -1).T

"""Layer-1 Pallas kernel: the per-step patch-group × kernels GEMM.

The accelerator's compute action ``a_6`` multiplies the im2col matrix of the
step's patch group, f32[G, D], by the resident kernel matrix, f32[D, N]
(D = C_in·H_K·W_K). This is the MAC hot-spot the paper's ``nbop_PE`` models.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * the group's working set (patches + kernels + outputs) is one step's
    on-chip footprint → it must fit VMEM, which is exactly the paper's
    Eq. 12 capacity constraint;
  * the GEMM itself targets the MXU; G is tiled by the grid so each grid
    step streams one patch-row tile HBM→VMEM — the BlockSpec realizes the
    ``I_slice`` load of the formalism;
  * ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
    custom-calls, so lowering stays in plain HLO (numerics identical).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _step_gemm_kernel(patches_ref, kernels_ref, out_ref):
    """One grid step: out tile [TG, N] = patch tile [TG, D] @ kernels [D, N]."""
    out_ref[...] = jnp.dot(
        patches_ref[...],
        kernels_ref[...],
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("tile_g",))
def step_gemm(patches, kernel_matrix, tile_g=8):
    """Pallas-backed per-step compute. Shapes: [G, D] @ [D, N] → [G, N].

    G is tiled by ``tile_g`` (padded if needed); D and N stay whole — per-step
    groups are small by construction (``nb_patches_max_S1``), so one kernel
    tile and one patch tile fit VMEM comfortably (see DESIGN.md §Perf for the
    footprint arithmetic).
    """
    g, d = patches.shape
    d2, n = kernel_matrix.shape
    assert d == d2, f"contraction mismatch: {d} vs {d2}"
    tile = min(tile_g, g)
    pad = (-g) % tile
    padded = jnp.pad(patches, ((0, pad), (0, 0))) if pad else patches
    gp = padded.shape[0]

    out = pl.pallas_call(
        _step_gemm_kernel,
        grid=(gp // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((d, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, n), jnp.float32),
        interpret=True,
    )(padded, kernel_matrix)
    return out[:g]


def _layer_gemm_kernel(cols_ref, kernels_ref, out_ref):
    out_ref[...] = jnp.dot(
        cols_ref[...],
        kernels_ref[...],
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("h_k", "w_k", "s_h", "s_w", "tile_g"))
def conv2d_im2col(inp, kernels, h_k, w_k, s_h=1, s_w=1, tile_g=8):
    """Whole-layer conv as im2col + the Pallas GEMM (the L2 path's hot-spot).

    ``inp`` f32[C_in, H_in, W_in]; ``kernels`` f32[N, C_in, H_K, W_K].
    Returns f32[N, H_out, W_out].
    """
    c_in, h_in, w_in = inp.shape
    n = kernels.shape[0]
    h_out = (h_in - h_k) // s_h + 1
    w_out = (w_in - w_k) // s_w + 1

    # Patch extraction via gather of strided windows (XLA fuses this).
    i_idx = jnp.arange(h_out) * s_h
    j_idx = jnp.arange(w_out) * s_w
    # windows[i, j, c, kh, kw] = inp[c, i*s_h + kh, j*s_w + kw]
    windows = jax.vmap(
        lambda i: jax.vmap(
            lambda j: jax.lax.dynamic_slice(inp, (0, i, j), (c_in, h_k, w_k))
        )(j_idx)
    )(i_idx)
    cols = windows.reshape(h_out * w_out, c_in * h_k * w_k)
    kmat = kernels.reshape(n, -1).T
    out = step_gemm(cols, kmat, tile_g=tile_g)  # [H_out*W_out, N]
    return out.T.reshape(n, h_out, w_out)

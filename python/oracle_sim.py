"""Independent Python oracle for the Rust offload simulator.

This module re-implements, from the paper's definitions alone (no shared
code), everything needed to replay a serialized offload schedule:

* the generalized convolution layer geometry (stride, dilation, channel
  groups) and its dilated patch footprints;
* the Definition-16 lowering of a grouped strategy to steps
  (load = footprint minus resident, free = resident minus footprint,
  write-back per policy, terminal flush);
* the Definition-3 duration model (element loads x t_l, write-backs x t_w,
  t_acc per compute step);
* the network-level chaining rules (2x2 mean-pool halves spatial dims,
  re-padding adds 2*pad per axis).

``python/tests/test_differential.py`` replays the JSON cases emitted by
``rust/tests/differential.rs`` (``target/differential_cases.json``) through
this oracle and asserts bit-equal durations and loaded-element counts.  The
module also re-implements the planner's analytic (anneal-free) lanes — the
four patch orderings and the greedy construction — which is how the
EXPERIMENTS.md baselines are cross-checked from a second code base.

Pure stdlib; footprints are Python ``set``s of pixel ids (correct and slow,
which is the point: an oracle should be obviously right, not fast).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field


# --------------------------------------------------------------- layer model


@dataclass(frozen=True)
class Layer:
    c_in: int
    h_in: int
    w_in: int
    h_k: int
    w_k: int
    n_kernels: int
    s_h: int = 1
    s_w: int = 1
    d_h: int = 1
    d_w: int = 1
    groups: int = 1

    def __post_init__(self):
        assert self.c_in % self.groups == 0, "groups must divide c_in"
        assert self.n_kernels % self.groups == 0, "groups must divide n_kernels"
        assert self.h_span <= self.h_in and self.w_span <= self.w_in

    @property
    def h_span(self) -> int:
        return (self.h_k - 1) * self.d_h + 1

    @property
    def w_span(self) -> int:
        return (self.w_k - 1) * self.d_w + 1

    @property
    def h_out(self) -> int:
        return (self.h_in - self.h_span) // self.s_h + 1

    @property
    def w_out(self) -> int:
        return (self.w_in - self.w_span) // self.s_w + 1

    @property
    def n_patches(self) -> int:
        return self.h_out * self.w_out

    @property
    def kernel_dims_len(self) -> int:
        """Elements of one kernel: (C_in / G) * H_K * W_K."""
        return (self.c_in // self.groups) * self.h_k * self.w_k

    @property
    def kernel_elements(self) -> int:
        return self.n_kernels * self.kernel_dims_len

    def patch_pixels(self, pid: int) -> set:
        """Dilated tap lattice of patch ``pid`` as a set of pixel ids."""
        i, j = divmod(pid, self.w_out)
        px = set()
        for h in range(self.h_k):
            row = (i * self.s_h + h * self.d_h) * self.w_in
            for w in range(self.w_k):
                px.add(row + j * self.s_w + w * self.d_w)
        return px

    def group_pixels(self, group) -> set:
        px = set()
        for p in group:
            px |= self.patch_pixels(p)
        return px


def layer_from_json(d: dict) -> Layer:
    return Layer(
        c_in=d["c_in"],
        h_in=d["h_in"],
        w_in=d["w_in"],
        h_k=d["h_k"],
        w_k=d["w_k"],
        n_kernels=d["n_kernels"],
        s_h=d["s_h"],
        s_w=d["s_w"],
        d_h=d.get("d_h", 1),
        d_w=d.get("d_w", 1),
        groups=d.get("groups", 1),
    )


# ------------------------------------------------------------ step semantics


@dataclass
class Accelerator:
    nbop_pe: int
    t_acc: int
    size_mem: int
    t_l: int
    t_w: int
    overlap: str = "sequential"  # or "double-buffered"
    dma_channels: int = 1
    compute_units: int = 1


def accelerator_from_json(d: dict) -> Accelerator:
    return Accelerator(
        nbop_pe=d["nbop_pe"],
        t_acc=d["t_acc"],
        size_mem=d["size_mem"],
        t_l=d["t_l"],
        t_w=d["t_w"],
        overlap=d.get("overlap", "sequential"),
        dma_channels=d.get("dma_channels", 1),
        compute_units=d.get("compute_units", 1),
    )


def for_group_size(layer: Layer, group: int) -> Accelerator:
    """The Rust ``Accelerator::for_group_size`` machine: §7.1 costs
    (t_l = t_acc = 1, writes free) with memory sized for kernels + ``group``
    input patches (all C_in channels) + their outputs."""
    ops_per_patch = layer.kernel_dims_len * layer.n_kernels
    input_elements_per_patch = layer.c_in * layer.h_k * layer.w_k
    mem = (
        layer.kernel_elements
        + group * input_elements_per_patch
        + group * layer.n_kernels
    )
    return Accelerator(
        nbop_pe=group * ops_per_patch, t_acc=1, size_mem=mem, t_l=1, t_w=0
    )


@dataclass
class StageResult:
    duration: int
    loaded_elements: int
    n_steps: int  # compute steps + terminal flush
    loaded_pixels: int  # spatial input pixels loaded (all steps)


def simulate_stage(
    layer: Layer,
    acc: Accelerator,
    groups,
    writeback: str = "every_step",
) -> StageResult:
    """Definition-16 lowering + Definition-3 costing of one grouped strategy.

    Mirrors the Rust ``GroupedStrategy::compile`` + ``sim::Simulator::run``
    contract: kernels load once on step 1, each step loads the missing part
    of its group's footprint and frees what the new group does not reuse,
    write-backs follow the policy, and a terminal flush (no compute) writes
    the remaining outputs.
    """
    assert writeback in ("every_step", "at_end")
    c_out = layer.n_kernels
    resident: set = set()
    pending_out = 0  # patches computed, not yet written
    duration = 0
    loaded_elements = 0
    loaded_pixels = 0
    seen = set()

    for k, group in enumerate(groups):
        assert group, "empty group in strategy"
        for p in group:
            assert p not in seen, f"patch {p} computed twice"
            seen.add(p)
        footprint = layer.group_pixels(group)
        load = footprint - resident
        # (a_1 frees resident - footprint; frees are cost-free)
        step_loaded = len(load) * layer.c_in
        if k == 0:
            step_loaded += layer.n_kernels * layer.kernel_dims_len
        written = pending_out * c_out if writeback == "every_step" else 0
        if writeback == "every_step":
            pending_out = 0
        duration += step_loaded * acc.t_l + written * acc.t_w + acc.t_acc
        loaded_elements += step_loaded
        loaded_pixels += len(load)
        pending_out += len(group)
        resident = footprint

    assert seen == set(range(layer.n_patches)), "strategy must cover X exactly"
    # Terminal flush: no compute, frees everything, writes what remains.
    duration += pending_out * c_out * acc.t_w
    return StageResult(
        duration=duration,
        loaded_elements=loaded_elements,
        n_steps=len(list(groups)) + 1,
        loaded_pixels=loaded_pixels,
    )


# ------------------------------------------------- overlapped timeline (§3.7)


@dataclass
class OverlapResult:
    makespan: int
    sequential_duration: int
    dma_busy: int
    compute_busy: int
    n_prefetched: int  # steps whose load overlapped the previous compute


class OverlapTimeline:
    """The two-resource recurrence (one DMA channel, one compute unit).

    Per step, the DMA channel runs the load phase then the write phase and
    the compute unit runs the compute phase.  A load may start during the
    previous step's compute only when ``can_prefetch`` (the double-buffer
    residency condition) held; otherwise it waits for that compute
    (serialization fallback).  Writes always wait for the compute that
    produced their values.  Mirrors ``rust/src/step/cost.rs``.
    """

    def __init__(self):
        self.dma_free = 0
        self.comp_end = 0
        self.dma_busy = 0
        self.compute_busy = 0

    def push(self, load, write, compute, can_prefetch):
        load_ready = 0 if can_prefetch else self.comp_end
        load_start = max(self.dma_free, load_ready)
        load_end = load_start + load
        write_end = max(load_end, self.comp_end) + write
        comp_end = max(load_end, self.comp_end) + compute
        self.dma_free = write_end
        self.comp_end = comp_end
        self.dma_busy += load + write
        self.compute_busy += compute

    def makespan(self):
        return max(self.dma_free, self.comp_end)


def simulate_stage_overlapped(
    layer: Layer,
    acc: Accelerator,
    groups,
    writeback: str = "every_step",
) -> OverlapResult:
    """Double-buffered replay of one grouped strategy.

    Same Definition-16 lowering as :func:`simulate_stage`; instead of
    summing step durations, phases are placed on the two-resource timeline.
    A step may prefetch its loads during the previous compute iff the
    previous step's on-chip occupancy plus the incoming elements fit in
    ``size_mem``.
    """
    assert writeback in ("every_step", "at_end")
    c_out = layer.n_kernels
    resident: set = set()
    pending_out = 0
    seen = set()
    timeline = OverlapTimeline()
    sequential = 0
    prev_occ = 0
    n_prefetched = 0

    for k, group in enumerate(groups):
        assert group, "empty group in strategy"
        for p in group:
            assert p not in seen, f"patch {p} computed twice"
            seen.add(p)
        footprint = layer.group_pixels(group)
        load = footprint - resident
        loaded_el = len(load) * layer.c_in
        if k == 0:
            loaded_el += layer.kernel_elements
        written = pending_out * c_out if writeback == "every_step" else 0
        if writeback == "every_step":
            pending_out = 0
        can_prefetch = prev_occ + loaded_el <= acc.size_mem
        n_prefetched += int(can_prefetch and k > 0)
        timeline.push(
            loaded_el * acc.t_l, written * acc.t_w, acc.t_acc, can_prefetch
        )
        sequential += loaded_el * acc.t_l + written * acc.t_w + acc.t_acc
        pending_out += len(group)
        resident = footprint
        prev_occ = (
            layer.kernel_elements
            + len(footprint) * layer.c_in
            + pending_out * c_out
        )

    assert seen == set(range(layer.n_patches)), "strategy must cover X exactly"
    # Terminal flush: no loads, no compute, the remaining write-backs.
    can_prefetch = prev_occ <= acc.size_mem
    timeline.push(0, pending_out * c_out * acc.t_w, 0, can_prefetch)
    sequential += pending_out * c_out * acc.t_w
    return OverlapResult(
        makespan=timeline.makespan(),
        sequential_duration=sequential,
        dma_busy=timeline.dma_busy,
        compute_busy=timeline.compute_busy,
        n_prefetched=n_prefetched,
    )


@dataclass
class MultiOverlapResult:
    makespan: int
    sequential_duration: int
    dma_busy: int
    compute_busy: int
    dma_busy_per: list
    compute_busy_per: list
    n_prefetched: int


class MultiResourceTimeline:
    """The generalized §3.10 timeline: k DMA channels x m compute units.

    List scheduling on the §3.7 (max,+) recurrence — each phase grabs the
    earliest-free resource of its class (lowest index on ties), dependencies
    unchanged. The write gate is anchored on ``prev_comp_end``, the compute
    frontier of the *producing* (previous in issue order) step, so the
    dependency survives m > 1 where "the busy compute unit" and "the unit
    that produced the outputs" stop coinciding. At k = m = 1 this collapses
    bit-exactly to :class:`OverlapTimeline`. Mirrors the generalized
    ``rust/src/step/cost.rs``.
    """

    def __init__(self, dma_channels: int = 1, compute_units: int = 1):
        assert dma_channels >= 1 and compute_units >= 1
        self.dma_free = [0] * dma_channels
        self.comp_free = [0] * compute_units
        self.prev_comp_end = 0
        self.dma_busy_per = [0] * dma_channels
        self.compute_busy_per = [0] * compute_units

    def begin_image(self):
        """Start the next image of a batch: steps of different images carry
        no data dependency, so only the issue-order compute gate resets —
        resource frontiers persist (the hardware is still busy)."""
        self.prev_comp_end = 0

    def push(self, load, write, compute, can_prefetch):
        gate = 0 if can_prefetch else self.prev_comp_end
        cl = min(range(len(self.dma_free)), key=self.dma_free.__getitem__)
        load_end = max(self.dma_free[cl], gate) + load
        self.dma_free[cl] = load_end
        self.dma_busy_per[cl] += load
        # The write drains outputs produced by the previous compute step:
        # re-pick the channel after the load so it lands on a free one.
        cw = min(range(len(self.dma_free)), key=self.dma_free.__getitem__)
        write_end = max(self.dma_free[cw], self.prev_comp_end) + write
        self.dma_free[cw] = write_end
        self.dma_busy_per[cw] += write
        u = min(range(len(self.comp_free)), key=self.comp_free.__getitem__)
        comp_end = max(self.comp_free[u], load_end, self.prev_comp_end) + compute
        self.comp_free[u] = comp_end
        self.compute_busy_per[u] += compute
        self.prev_comp_end = comp_end
        return cl, cw, u

    @property
    def dma_busy(self):
        return sum(self.dma_busy_per)

    @property
    def compute_busy(self):
        return sum(self.compute_busy_per)

    def makespan(self):
        return max(self.dma_free + self.comp_free)


def simulate_stage_multi(
    layer: Layer,
    acc: Accelerator,
    groups,
    writeback: str = "every_step",
    batch: int = 1,
) -> MultiOverlapResult:
    """Multi-resource double-buffered replay of one grouped strategy over a
    batch of ``batch`` images.

    Same Definition-16 lowering as :func:`simulate_stage_overlapped`, placed
    on the k x m :class:`MultiResourceTimeline`. Kernels load once: images
    after the first subtract the kernel elements from step 0's load (the
    weights stay resident across the flush in the cost model). The terminal
    flush leaves on-chip memory empty, so each image replays the identical
    step stream; ``begin_image`` resets only the issue-order compute gate,
    letting the next image's phases pipeline onto free units.
    """
    assert batch >= 1
    shapes = _stage_step_shapes(layer, groups, writeback)
    timeline = MultiResourceTimeline(acc.dma_channels, acc.compute_units)
    sequential = 0
    prev_occ = 0
    n_prefetched = 0
    for b in range(batch):
        if b > 0:
            timeline.begin_image()
        for i, (loaded, written, computed, occ) in enumerate(shapes):
            if b > 0 and i == 0:
                loaded -= layer.kernel_elements
            compute = acc.t_acc if computed else 0
            can_prefetch = prev_occ + loaded <= acc.size_mem
            n_prefetched += int(can_prefetch and computed and (i > 0 or b > 0))
            timeline.push(
                loaded * acc.t_l, written * acc.t_w, compute, can_prefetch
            )
            sequential += loaded * acc.t_l + written * acc.t_w + compute
            prev_occ = occ
    return MultiOverlapResult(
        makespan=timeline.makespan(),
        sequential_duration=sequential,
        dma_busy=timeline.dma_busy,
        compute_busy=timeline.compute_busy,
        dma_busy_per=list(timeline.dma_busy_per),
        compute_busy_per=list(timeline.compute_busy_per),
        n_prefetched=n_prefetched,
    )


def analytic_portfolio_overlapped(layer: Layer, group_size: int):
    """The planner's anneal-free lanes raced under the double-buffered
    makespan on the ``for_group_size`` machine — winner by
    (makespan, loaded pixels, lane order), mirroring the Rust reduction.
    Returns (winner_label, makespan, per-lane dict)."""
    acc = for_group_size(layer, group_size)
    k = -(-layer.n_patches // group_size)
    lanes = []
    for name in ("row-by-row", "zigzag", "hilbert", "diagonal"):
        groups = order_to_groups(ORDERINGS[name](layer), group_size)
        lanes.append(
            (
                name,
                simulate_stage_overlapped(layer, acc, groups).makespan,
                grouping_loaded_pixels(layer, groups),
            )
        )
    greedy = greedy_groups(layer, k)
    lanes.append(
        (
            "greedy",
            simulate_stage_overlapped(layer, acc, greedy).makespan,
            grouping_loaded_pixels(layer, greedy),
        )
    )
    best = min(lanes, key=lambda t: (t[1], t[2]))  # stable: earliest lane wins
    return best[0], best[1], {name: m for name, m, _ in lanes}


# ------------------------------------------------------------- network level


def next_stage_dims(layer: Layer, pool_after: bool, pad_after: int):
    c, h, w = layer.n_kernels, layer.h_out, layer.w_out
    if pool_after:
        h //= 2
        w //= 2
    return c, h + 2 * pad_after, w + 2 * pad_after


def replay_case(case: dict) -> dict:
    """Replay one differential case (a serialized fuzz network).

    Returns the oracle's per-stage results — sequential, double-buffered,
    and double-buffered with a 2x memory ("roomy": most prefetches succeed,
    so real overlap is exercised) — plus, when the case carries sampled
    ``dma_channels`` / ``compute_units`` / ``batch`` fields (interchange
    v4), the multi-resource batched replay on the roomy variant — plus the
    chained-dimension check; raises AssertionError on any structural
    violation.
    """
    from dataclasses import replace

    per_stage = []
    overlapped = []
    overlapped_roomy = []
    multi = []
    kch = case.get("dma_channels", 0)
    mcu = case.get("compute_units", 0)
    batch = case.get("batch", 1)
    prev = None
    for st in case["stages"]:
        layer = layer_from_json(st["layer"])
        if prev is not None:
            expect = next_stage_dims(*prev)
            got = (layer.c_in, layer.h_in, layer.w_in)
            assert got == expect, f"stage chaining broken: {got} != {expect}"
        acc = accelerator_from_json(st["accelerator"])
        writeback = st.get("writeback", "every_step")
        res = simulate_stage(layer, acc, st["strategy_groups"], writeback)
        ovl = simulate_stage_overlapped(layer, acc, st["strategy_groups"], writeback)
        roomy = simulate_stage_overlapped(
            layer,
            replace(acc, size_mem=acc.size_mem * 2),
            st["strategy_groups"],
            writeback,
        )
        # Internal consistency: the two codepaths must agree on the
        # sequential duration, and the makespan obeys its analytic bounds.
        assert ovl.sequential_duration == res.duration
        for r in (ovl, roomy):
            assert r.makespan <= res.duration
            assert r.makespan >= max(r.dma_busy, r.compute_busy)
        if kch and mcu:
            mr = simulate_stage_multi(
                layer,
                replace(
                    acc,
                    size_mem=acc.size_mem * 2,
                    dma_channels=kch,
                    compute_units=mcu,
                ),
                st["strategy_groups"],
                writeback,
                batch=batch,
            )
            assert mr.makespan <= mr.sequential_duration
            assert mr.makespan >= max(
                -(-mr.dma_busy // kch), -(-mr.compute_busy // mcu)
            )
            multi.append(mr)
        per_stage.append(res)
        overlapped.append(ovl)
        overlapped_roomy.append(roomy)
        prev = (layer, st["pool_after"], st["pad_after"])
    return {
        "per_stage": per_stage,
        "total_duration": sum(r.duration for r in per_stage),
        "overlapped": overlapped,
        "overlapped_total": sum(r.makespan for r in overlapped),
        "overlapped_roomy": overlapped_roomy,
        "overlapped_roomy_total": sum(r.makespan for r in overlapped_roomy),
        "multi": multi,
        "multi_total": sum(r.makespan for r in multi),
    }


# ----------------------------------------------- analytic planner lanes
# Re-implementations of the Rust ordering generators and the greedy
# construction, used to cross-check the EXPERIMENTS.md planner baselines.


def row_major_order(layer: Layer):
    return list(range(layer.n_patches))


def zigzag_order(layer: Layer):
    order = []
    for i in range(layer.h_out):
        js = range(layer.w_out) if i % 2 == 0 else range(layer.w_out - 1, -1, -1)
        order.extend(i * layer.w_out + j for j in js)
    return order


def _hilbert_d2xy(side: int, d: int):
    x = y = 0
    t = d
    s = 1
    while s < side:
        rx = 1 & (t // 2)
        ry = 1 & (t ^ rx)
        if ry == 0:
            if rx == 1:
                x, y = s - 1 - x, s - 1 - y
            x, y = y, x
        x += s * rx
        y += s * ry
        t //= 4
        s *= 2
    return x, y


def hilbert_order(layer: Layer):
    side = 1
    while side < max(layer.h_out, layer.w_out):
        side *= 2
    order = []
    for d in range(side * side):
        x, y = _hilbert_d2xy(side, d)
        if y < layer.h_out and x < layer.w_out:
            order.append(y * layer.w_out + x)
    return order


def diagonal_order(layer: Layer):
    order = []
    for d in range(layer.h_out + layer.w_out - 1):
        for i in range(layer.h_out):
            if d >= i and d - i < layer.w_out:
                order.append(i * layer.w_out + (d - i))
    return order


ORDERINGS = {
    "row-by-row": row_major_order,
    "zigzag": zigzag_order,
    "hilbert": hilbert_order,
    "diagonal": diagonal_order,
}


def order_to_groups(order, group_size: int):
    return [order[i : i + group_size] for i in range(0, len(order), group_size)]


def _group_sizes(n: int, k: int):
    base, extra = divmod(n, k)
    return [base + (1 if i < extra else 0) for i in range(k)]


def greedy_groups(layer: Layer, k: int):
    """The Rust ``optimizer::search::greedy`` scan, including its tie-break
    behavior: candidates live in a work list mutated by swap-remove, score =
    2x overlap with the group under construction + overlap with the previous
    group, strict improvement keeps the earliest entry."""
    unassigned = list(range(layer.n_patches))
    pix = {p: layer.patch_pixels(p) for p in unassigned}
    groups = []
    prev: set = set()
    for size in _group_sizes(layer.n_patches, k):
        group = []
        fp: set = set()
        for _ in range(size):
            best_idx, best_score = 0, -1
            for idx, p in enumerate(unassigned):
                score = 2 * len(pix[p] & fp) + len(pix[p] & prev)
                if score > best_score:
                    best_score, best_idx = score, idx
            # swap_remove: replace with the last element, pop the tail
            p = unassigned[best_idx]
            unassigned[best_idx] = unassigned[-1]
            unassigned.pop()
            fp |= pix[p]
            group.append(p)
        prev = fp
        groups.append(group)
    return groups


def grouping_loaded_pixels(layer: Layer, groups) -> int:
    """Total spatial pixels loaded: sum of footprints minus consecutive
    overlaps (the planner's race objective)."""
    total = 0
    resident: set = set()
    for g in groups:
        fp = layer.group_pixels(g)
        total += len(fp - resident)
        resident = fp
    return total


def analytic_portfolio(layer: Layer, group_size: int):
    """The planner's anneal-free lanes in portfolio order: the four orderings
    chunked to ``group_size`` plus greedy over ``k = ceil(|X|/g)`` balanced
    groups. Returns (winner_label, loaded_pixels, per-lane dict)."""
    k = -(-layer.n_patches // group_size)
    lanes = []
    for name in ("row-by-row", "zigzag", "hilbert", "diagonal"):
        groups = order_to_groups(ORDERINGS[name](layer), group_size)
        lanes.append((name, grouping_loaded_pixels(layer, groups)))
    lanes.append(("greedy", grouping_loaded_pixels(layer, greedy_groups(layer, k))))
    best = min(lanes, key=lambda t: t[1])  # min is stable: earliest lane wins ties
    return best[0], best[1], dict(lanes)


# ------------------------------------------------------------ batch planning


def cache_key(
    layer: Layer,
    acc: Accelerator,
    group_size: int,
    k: int,
    seed: int,
    anneal_iters: int,
    anneal_starts: int,
) -> str:
    """Mirror of the Rust planner's ``CacheKey`` v4 canonical string
    (``rust/src/planner/cache.rs``): everything a planned strategy depends
    on — layer geometry, accelerator parameters, overlap mode, resource
    shape (DMA channels x compute units), grouping bounds and the portfolio
    configuration. The differential suite uses it to reproduce the batch
    planner's cross-network dedup accounting from an independent code
    base."""
    return (
        f"v4|in:{layer.c_in}x{layer.h_in}x{layer.w_in}"
        f"|ker:{layer.n_kernels}x{layer.h_k}x{layer.w_k}"
        f"|stride:{layer.s_h}x{layer.s_w}"
        f"|dil:{layer.d_h}x{layer.d_w}"
        f"|grp:{layer.groups}"
        f"|acc:{acc.nbop_pe},{acc.t_acc},{acc.size_mem},{acc.t_l},{acc.t_w}"
        f"|ovl:{acc.overlap}"
        f"|ch:{acc.dma_channels}x{acc.compute_units}"
        f"|g:{group_size}"
        f"|k:{k}"
        f"|anneal:{anneal_starts}x{anneal_iters}@{seed}"
    )


def batch_dedup(
    networks,
    group_size: int,
    seed: int = 2026,
    anneal_iters: int = 50_000,
    anneal_starts: int = 3,
    overlap: str = "sequential",
) -> dict:
    """Mirror of the Rust ``BatchPlanner`` dedup accounting: canonicalize
    every stage of every network (a list of ``Layer`` lists) to its cache
    key on the ``for_group_size`` machine, then count, in batch order, the
    stages whose problem was already seen (``dedup_hits``) and the subset
    first seen in a *different* network (``cross_network_dedup_hits``)."""
    first_net: dict = {}
    stages_total = 0
    dedup_hits = 0
    cross_network_dedup_hits = 0
    for ni, layers in enumerate(networks):
        for layer in layers:
            stages_total += 1
            acc = for_group_size(layer, group_size)
            acc.overlap = overlap
            k = -(-layer.n_patches // group_size)
            key = cache_key(
                layer, acc, group_size, k, seed, anneal_iters, anneal_starts
            )
            if key in first_net:
                dedup_hits += 1
                if first_net[key] != ni:
                    cross_network_dedup_hits += 1
            else:
                first_net[key] = ni
    return {
        "stages_total": stages_total,
        "unique_problems": stages_total - dedup_hits,
        "dedup_hits": dedup_hits,
        "cross_network_dedup_hits": cross_network_dedup_hits,
    }


# ------------------------------------------------------ fault injection (§3.9)
#
# A bit-exact mirror of the Rust fault subsystem (`rust/src/util/rng.rs`,
# `rust/src/platform/fault.rs`, the fault arms of `rust/src/sim/engine.rs`
# and `rust/src/step/cost.rs`). The RNG is xoshiro256** seeded through
# SplitMix64; every step of a run draws its faults from a *stateless*
# per-step stream (`seed ^ index * GOLDEN`), so the cross-language contract
# is: same seed, same step shapes -> the same retries, jitters and shrink
# events, to the bit.

_M64 = (1 << 64) - 1

#: SplitMix64's increment, also the per-step stream spreader (Rust GOLDEN).
GOLDEN = 0x9E3779B97F4A7C15


def splitmix64(state: int):
    """One SplitMix64 step; returns ``(next_state, output)``."""
    state = (state + GOLDEN) & _M64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return state, z ^ (z >> 31)


def _rotl(x: int, k: int) -> int:
    return ((x << k) | (x >> (64 - k))) & _M64


class Rng:
    """xoshiro256** with SplitMix64 seeding — the Rust ``util::rng::Rng``."""

    def __init__(self, seed: int):
        s = seed & _M64
        self.s = []
        for _ in range(4):
            s, out = splitmix64(s)
            self.s.append(out)

    def next_u64(self) -> int:
        s = self.s
        result = (_rotl((s[1] * 5) & _M64, 7) * 9) & _M64
        t = (s[1] << 17) & _M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def below(self, bound: int) -> int:
        """Uniform in [0, bound) via Lemire multiply-shift rejection."""
        assert bound > 0
        threshold = (_M64 - bound + 1) % bound
        while True:
            x = self.next_u64()
            m = x * bound
            lo = m & _M64
            if lo >= bound or lo >= threshold:
                return (m >> 64) & _M64

    def f64(self) -> float:
        # Exact: a 53-bit integer scaled by 2^-53 is one FP multiply with no
        # rounding, so Rust and CPython produce the identical double.
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def chance(self, p: float) -> bool:
        return self.f64() < p


@dataclass
class StepFaults:
    """Faults drawn for one step (mirror of ``platform::StepFaults``)."""

    load_retries: int = 0
    dma_jitter: int = 0
    compute_jitter: int = 0
    shrink: bool = False


@dataclass(frozen=True)
class FaultModel:
    """Mirror of ``platform::FaultModel`` (field-for-field)."""

    seed: int = 0
    dma_fail_rate: float = 0.0
    max_retries: int = 0
    retry_penalty: int = 0
    dma_jitter: int = 0
    t_acc_jitter: int = 0
    shrink_rate: float = 0.0
    shrink_elements: int = 0

    def is_active(self) -> bool:
        return (
            (self.dma_fail_rate > 0.0 and self.max_retries > 0)
            or self.dma_jitter > 0
            or self.t_acc_jitter > 0
            or (self.shrink_rate > 0.0 and self.shrink_elements > 0)
        )

    def for_stage(self, stage: int) -> "FaultModel":
        """The stage-``stage`` view of this model: the same axes with the
        stage index golden-ratio-mixed into the seed (wrapping add, distinct
        from the per-step xor spreading), so different pipeline stages draw
        decorrelated streams. Stage 0 is the identity — single-stage traces
        are unchanged. Mirror of ``platform::FaultModel::for_stage``."""
        from dataclasses import replace

        return replace(
            self, seed=(self.seed + ((stage * GOLDEN) & _M64)) & _M64
        )

    def step_faults(
        self, index: int, loaded_elements: int, written_elements: int, computed: bool
    ) -> StepFaults:
        """The cross-language draw order: retries (while the load keeps
        failing, capped), DMA jitter (steps that load or write), compute
        jitter (compute steps), then the shrink event — each draw gated on
        the step's shape so empty phases consume nothing."""
        f = StepFaults()
        if not self.is_active():
            return f
        rng = Rng(self.seed ^ ((index * GOLDEN) & _M64))
        if self.dma_fail_rate > 0.0 and loaded_elements > 0:
            for _ in range(self.max_retries):
                if rng.chance(self.dma_fail_rate):
                    f.load_retries += 1
                else:
                    break
        if self.dma_jitter > 0 and (loaded_elements > 0 or written_elements > 0):
            f.dma_jitter = rng.below(self.dma_jitter + 1)
        if self.t_acc_jitter > 0 and computed:
            f.compute_jitter = rng.below(self.t_acc_jitter + 1)
        if self.shrink_rate > 0.0 and self.shrink_elements > 0:
            f.shrink = rng.chance(self.shrink_rate)
        return f

    def makespan_under_k_faults(
        self,
        fault_free_duration: int,
        n_steps: int,
        n_compute_steps: int,
        max_load_cycles: int,
        k: int,
    ) -> int:
        """The analytic worst case: the fault-free sum plus every jitter at
        its cap plus ``k`` replays of the largest load (each with the retry
        penalty). Monotone in ``k``; dominates every trace with <= k retries
        under both overlap modes."""
        return (
            fault_free_duration
            + n_steps * self.dma_jitter
            + n_compute_steps * self.t_acc_jitter
            + k * (max_load_cycles + self.retry_penalty)
        )


def fault_model_from_json(d: dict) -> FaultModel:
    """Read the interchange form (field names = the `[faults]` TOML keys)."""
    return FaultModel(
        seed=d["seed"],
        dma_fail_rate=d["dma_fail_rate"],
        max_retries=d["max_retries"],
        retry_penalty=d["retry_penalty"],
        dma_jitter=d["dma_jitter"],
        t_acc_jitter=d["t_acc_jitter"],
        shrink_rate=d["shrink_rate"],
        shrink_elements=d["shrink_elements"],
    )


def _stage_step_shapes(layer: Layer, groups, writeback: str):
    """The Definition-16 step stream of one grouped strategy, reduced to the
    shapes fault draws and costs depend on: per step
    ``(loaded_elements, written_elements, computed, occupancy_after)`` —
    compute steps in order, then the terminal flush. The occupancy is the
    post-step on-chip total (kernels + resident inputs + pending outputs),
    the left side of the §3.7 residency condition for the *next* step."""
    assert writeback in ("every_step", "at_end")
    c_out = layer.n_kernels
    resident: set = set()
    pending_out = 0
    seen = set()
    shapes = []
    for k, group in enumerate(groups):
        assert group, "empty group in strategy"
        for p in group:
            assert p not in seen, f"patch {p} computed twice"
            seen.add(p)
        footprint = layer.group_pixels(group)
        load = footprint - resident
        loaded_el = len(load) * layer.c_in
        if k == 0:
            loaded_el += layer.kernel_elements
        written = pending_out * c_out if writeback == "every_step" else 0
        if writeback == "every_step":
            pending_out = 0
        pending_out += len(group)
        resident = footprint
        occupancy = (
            layer.kernel_elements + len(footprint) * layer.c_in + pending_out * c_out
        )
        shapes.append((loaded_el, written, True, occupancy))
    assert seen == set(range(layer.n_patches)), "strategy must cover X exactly"
    shapes.append((0, pending_out * c_out, False, 0))
    return shapes


@dataclass
class FaultedStageResult:
    duration: int  # faulted Definition-3 sum (sequential mode)
    fault_retries: int
    mem_shrink_events: int
    wcet_bound: int
    n_steps: int


def simulate_stage_faulted(
    layer: Layer,
    acc: Accelerator,
    groups,
    model: FaultModel,
    writeback: str = "every_step",
) -> FaultedStageResult:
    """Sequential replay under fault injection (mirror of the fault arm of
    ``sim::engine::execute_steps``): per step, the load phase pays each
    retry a full replay plus the penalty and the drawn DMA jitter, the
    compute phase pays its jitter, writes are never jittered. An inactive
    model reproduces :func:`simulate_stage` bit-exactly."""
    shapes = _stage_step_shapes(layer, groups, writeback)
    duration = 0
    clean = 0
    retries = 0
    shrinks = 0
    max_load_cycles = 0
    for i, (loaded, written, computed, _occ) in enumerate(shapes):
        fx = model.step_faults(i, loaded, written, computed)
        if fx.shrink:
            shrinks += 1
        retries += fx.load_retries
        load_cycles = loaded * acc.t_l
        max_load_cycles = max(max_load_cycles, load_cycles)
        compute = acc.t_acc if computed else 0
        clean += load_cycles + written * acc.t_w + compute
        duration += (
            load_cycles
            + fx.load_retries * (load_cycles + model.retry_penalty)
            + fx.dma_jitter
            + written * acc.t_w
            + compute
            + fx.compute_jitter
        )
    n_compute = sum(1 for s in shapes if s[2])
    wcet = model.makespan_under_k_faults(
        clean, len(shapes), n_compute, max_load_cycles, retries
    )
    assert wcet >= duration, "WCET bound below a simulated sequential trace"
    return FaultedStageResult(
        duration=duration,
        fault_retries=retries,
        mem_shrink_events=shrinks,
        wcet_bound=wcet,
        n_steps=len(shapes),
    )


@dataclass
class FaultedOverlapResult:
    makespan: int
    sequential_duration: int  # the faulted Definition-3 sum
    fault_retries: int
    mem_shrink_events: int
    wcet_bound: int
    dma_busy: int
    compute_busy: int


def simulate_stage_overlapped_faulted(
    layer: Layer,
    acc: Accelerator,
    groups,
    model: FaultModel,
    writeback: str = "every_step",
) -> FaultedOverlapResult:
    """Double-buffered replay under fault injection: the same faulted phase
    durations placed on the two-resource timeline, with the §3.7 residency
    condition checked against the *effective* memory budget — which shrinks
    stickily as ``MemoryShrink`` events fire (before the same step's own
    residency check, as in the Rust engine)."""
    shapes = _stage_step_shapes(layer, groups, writeback)
    timeline = OverlapTimeline()
    effective_mem = acc.size_mem
    prev_occ = 0
    sequential = 0
    clean = 0
    retries = 0
    shrinks = 0
    max_load_cycles = 0
    for i, (loaded, written, computed, occ) in enumerate(shapes):
        fx = model.step_faults(i, loaded, written, computed)
        if fx.shrink:
            shrinks += 1
            effective_mem = max(0, effective_mem - model.shrink_elements)
        retries += fx.load_retries
        load_cycles = loaded * acc.t_l
        max_load_cycles = max(max_load_cycles, load_cycles)
        faulted_load = (
            load_cycles
            + fx.load_retries * (load_cycles + model.retry_penalty)
            + fx.dma_jitter
        )
        write_cycles = written * acc.t_w
        compute = acc.t_acc if computed else 0
        faulted_compute = compute + fx.compute_jitter
        can_prefetch = prev_occ + loaded <= effective_mem
        timeline.push(faulted_load, write_cycles, faulted_compute, can_prefetch)
        prev_occ = occ
        clean += load_cycles + write_cycles + compute
        sequential += faulted_load + write_cycles + faulted_compute
    n_compute = sum(1 for s in shapes if s[2])
    wcet = model.makespan_under_k_faults(
        clean, len(shapes), n_compute, max_load_cycles, retries
    )
    makespan = timeline.makespan()
    assert makespan <= sequential, "timeline above the faulted sum"
    assert wcet >= makespan, "WCET bound below a simulated overlapped trace"
    return FaultedOverlapResult(
        makespan=makespan,
        sequential_duration=sequential,
        fault_retries=retries,
        mem_shrink_events=shrinks,
        wcet_bound=wcet,
        dma_busy=timeline.dma_busy,
        compute_busy=timeline.compute_busy,
    )


def replay_case_faulted(case: dict, model: FaultModel) -> dict:
    """Replay one differential case under fault injection: every stage of
    the network sequentially and double-buffered on its own accelerator.
    Stage ``i`` draws from ``model.for_stage(i)`` — stage-decorrelated
    streams, as in ``Network::run_with_faults`` — so step 0 of different
    stages no longer shares a stream (stage 0 keeps the bare model).
    Returns the per-stage results plus network totals."""
    per_stage = []
    overlapped = []
    for i, st in enumerate(case["stages"]):
        layer = layer_from_json(st["layer"])
        acc = accelerator_from_json(st["accelerator"])
        writeback = st.get("writeback", "every_step")
        stage_model = model.for_stage(i)
        per_stage.append(
            simulate_stage_faulted(
                layer, acc, st["strategy_groups"], stage_model, writeback
            )
        )
        overlapped.append(
            simulate_stage_overlapped_faulted(
                layer, acc, st["strategy_groups"], stage_model, writeback
            )
        )
    return {
        "per_stage": per_stage,
        "total_duration": sum(r.duration for r in per_stage),
        "fault_retries": sum(r.fault_retries for r in per_stage),
        "mem_shrink_events": sum(r.mem_shrink_events for r in per_stage),
        "wcet_bound": sum(r.wcet_bound for r in per_stage),
        "overlapped": overlapped,
        "overlapped_total": sum(r.makespan for r in overlapped),
    }


# ------------------------------------------------- plan-server decision logic

# The plan-server's load-shedding and crash-recovery decisions are pure
# functions in Rust (``server::admission``, ``server::journal::replay_lines``)
# precisely so this oracle can reproduce them bit-exactly without a Rust
# toolchain.  ``python/tests/test_server_oracle.py`` pins the identical
# decision tables as the Rust unit tests.

RUNGS = ("full", "reduced", "heuristic", "cache-only")
"""Degradation ladder, least to most degraded (``server::admission::Rung``)."""

JOURNAL_VERSION = 1
"""Journal record version (``server::journal::JOURNAL_VERSION``)."""


def select_rung(queue_depth: int, queue_capacity: int, budget_ms):
    """Mirror of ``server::admission::select_rung``.

    Combines queue pressure and the request's time budget; the more
    degraded signal wins.  Returns one of ``RUNGS``.
    """
    if queue_depth == 0:
        by_queue = "full"
    elif queue_depth * 2 <= queue_capacity:
        by_queue = "reduced"
    elif queue_depth < queue_capacity:
        by_queue = "heuristic"
    else:
        by_queue = "cache-only"
    if budget_ms is None or budget_ms >= 1_000:
        by_budget = "full"
    elif budget_ms >= 100:
        by_budget = "reduced"
    elif budget_ms >= 10:
        by_budget = "heuristic"
    else:
        by_budget = "cache-only"
    return max(by_queue, by_budget, key=RUNGS.index)


def rung_budgets(rung: str, starts: int, iters: int):
    """Mirror of ``server::admission::rung_budgets``: the portfolio budget
    ``(anneal_starts, anneal_iters)`` a rung runs, or ``None`` for the
    cache-only rung (no race admitted at all)."""
    if rung == "full":
        return (starts, iters)
    if rung == "reduced":
        return (1, iters // 4)
    if rung == "heuristic":
        return (0, 0)
    if rung == "cache-only":
        return None
    raise ValueError(f"unknown rung {rung!r}")


def _journal_u64(v):
    """The Rust ``Json::as_u64``: a non-negative integer-valued number
    (booleans are a distinct JSON type and never numbers)."""
    if isinstance(v, bool):
        return None
    if isinstance(v, int):
        return v if v >= 0 else None
    if isinstance(v, float) and v.is_integer() and v >= 0:
        return int(v)
    return None


def _journal_record(line: str):
    """Parse one journal line into ``(event, id, req)``; raises on any
    malformation (mirror of ``server::journal::parse_record``)."""
    v = json.loads(line)
    if not isinstance(v, dict):
        raise ValueError("record is not an object")
    if _journal_u64(v.get("v")) != JOURNAL_VERSION:
        raise ValueError("bad or missing journal version")
    rec_id = _journal_u64(v.get("id"))
    if rec_id is None:
        raise ValueError("bad or missing record id")
    event = v.get("e")
    if event == "recv":
        req = v.get("req")
        if req is None:
            raise ValueError("recv record without req")
        if not isinstance(req, dict):
            raise ValueError("recv req is not an object")
        return ("recv", rec_id, req)
    if event == "done":
        return ("done", rec_id, None)
    raise ValueError("unknown record event")


def journal_replay(lines):
    """Mirror of ``server::journal::replay_lines``: pair ``recv`` records
    with their ``done`` records.

    Returns ``{"pending": [(id, req), ...], "torn_tail": bool,
    "next_id": int}``.  Blank lines are skipped; a malformed **last** line
    is dropped as a torn tail; a malformed interior line or a duplicate
    pending ``recv`` id raises ``ValueError`` (the Rust caller quarantines
    the file); a ``done`` without a matching ``recv`` is ignored.
    """
    pending = []
    torn_tail = False
    next_id = 0
    last = max(len(lines) - 1, 0)
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            event, rec_id, req = _journal_record(line)
        except (ValueError, json.JSONDecodeError) as e:
            if i == last:
                torn_tail = True
                continue
            raise ValueError(f"journal corrupt at line {i + 1}: {e}") from None
        next_id = max(next_id, rec_id + 1)
        if event == "recv":
            if any(p == rec_id for p, _ in pending):
                raise ValueError(
                    f"journal corrupt at line {i + 1}: duplicate recv id {rec_id}"
                )
            pending.append((rec_id, req))
        else:
            pending = [(p, r) for p, r in pending if p != rec_id]
    return {"pending": pending, "torn_tail": torn_tail, "next_id": next_id}


# ------------------------------------------- optimality certification (§3.12)
#
# Mirror of ``rust/src/planner/certify.rs``: the analytic per-layer
# communication lower bound (arxiv 1911.05662 adapted to the patch/grouping
# model) plus a tiny brute-force exact grouping solve. The bound is
# deliberately derived twice — here from the paper's formulas on Python
# sets, in Rust on ``PixelSet`` bitsets — so the gap pins in CI are
# cross-language evidence, not one implementation checking itself.


def layer_union_pixels(layer: Layer) -> int:
    """``|U|``: distinct input pixels tapped by any patch — the cold-load
    floor. Exact under stride / dilation / channel groups because it is
    computed from the actual dilated tap lattices, not a closed form."""
    seen: set = set()
    for p in range(layer.n_patches):
        seen |= layer.patch_pixels(p)
    return len(seen)


def comm_lower_bound(layer: Layer, acc: Accelerator) -> dict:
    """Floor on the traffic of *any* valid grouped strategy (DESIGN.md §3.12).

    Pixel domain: ``bound_pixels = max(cold_pixels, memory_pixels)`` where

    * ``cold_pixels = |U|`` — every used pixel is loaded at least once
      (consecutive-group reuse frees everything else, so this is exact);
    * ``memory_pixels`` — the 1911.05662-style memory-dependent term: with
      at most ``P_cap = (size_mem - kernel_elements) / c_in`` resident
      pixels, reloads are forced once the per-patch private area
      ``a x b`` (``a = min(s_h, h_span)``, ``b = min(s_w, w_span)``)
      summed over patches exceeds capacity. Conservative divisor 2 keeps
      it a true floor for every grouping; it is monotone non-increasing
      in ``size_mem`` (the property the test suite pins).

    Element domain: input floor ``bound_pixels * c_in`` plus the one-time
    kernel load; write floor ``n_patches * n_kernels`` (every output leaves
    exactly once); step floor ``ceil(n_patches / max_patches_per_step)``.
    """
    n = layer.n_patches
    cold = layer_union_pixels(layer)
    a = min(layer.s_h, layer.h_span)
    b = min(layer.s_w, layer.w_span)
    cap_el = max(acc.size_mem - layer.kernel_elements, 0)
    p_cap = cap_el // layer.c_in if layer.c_in else cap_el
    memory_px = max(n * a * b - p_cap, 0) // 2
    bound_px = max(cold, memory_px)
    input_floor = bound_px * layer.c_in
    ops_per_patch = layer.kernel_dims_len * layer.n_kernels
    max_pps = max(acc.nbop_pe // ops_per_patch, 1) if ops_per_patch else max(n, 1)
    return {
        "cold_pixels": cold,
        "memory_pixels": memory_px,
        "bound_pixels": bound_px,
        "input_element_floor": input_floor,
        "kernel_elements": layer.kernel_elements,
        "load_element_floor": input_floor + layer.kernel_elements,
        "write_element_floor": n * layer.n_kernels,
        "min_compute_steps": -(-n // max_pps),
    }


def optimality_gap(achieved: int, bound: int) -> float:
    """``(achieved - bound) / bound`` as an IEEE double, 0.0 when the bound
    is zero or already met. Both languages divide the same two exact
    integers, so the value is bit-identical cross-language."""
    if bound == 0:
        return 0.0
    return max(achieved - bound, 0) / bound


def exact_min_loaded_pixels(layer: Layer, g: int, k: int):
    """Brute-force exact optimum of the grouping problem: the minimum
    ``grouping_loaded_pixels`` over all ordered partitions of the patch set
    into exactly ``k`` non-empty groups of size <= ``g`` (the same space
    ``optimizer::exact::solve_exact`` searches). Returns
    ``(best_cost, best_groups)`` or ``None`` if the shape is infeasible.

    Exponential and meant for micro instances only (n <= ~8); within-group
    order is quotiented out because a group's footprint is order-free.
    """
    from itertools import combinations

    n = layer.n_patches
    if k * g < n or k > n or n == 0:
        return None
    pix = [layer.patch_pixels(p) for p in range(n)]
    best_cost = None
    best_groups = None

    def dfs(remaining, groups, prev_fp, cost):
        nonlocal best_cost, best_groups
        if best_cost is not None and cost >= best_cost:
            return
        slots_left = k - len(groups)
        if slots_left == 0:
            if not remaining:
                best_cost, best_groups = cost, [list(gr) for gr in groups]
            return
        rem = sorted(remaining)
        lo = max(1, len(rem) - (slots_left - 1) * g)
        hi = min(g, len(rem) - (slots_left - 1))
        for size in range(lo, hi + 1):
            for combo in combinations(rem, size):
                fp = set()
                for p in combo:
                    fp |= pix[p]
                dfs(
                    remaining - set(combo),
                    groups + [combo],
                    fp,
                    cost + len(fp - prev_fp),
                )

    dfs(frozenset(range(n)), [], set(), 0)
    if best_cost is None:
        return None
    return best_cost, best_groups


def certify_stage(layer: Layer, acc: Accelerator, group_size: int) -> dict:
    """Bound + portfolio replay for one planning problem: what the Rust
    ``certify`` CLI reports per stage, re-derived independently. Gap is in
    the pixel domain (the planner's race objective)."""
    winner, achieved_px, _ = analytic_portfolio(layer, group_size)
    bound = comm_lower_bound(layer, acc)
    return {
        "winner": winner,
        "achieved_pixels": achieved_px,
        "bound_pixels": bound["bound_pixels"],
        "optimality_gap": optimality_gap(achieved_px, bound["bound_pixels"]),
        "bound": bound,
    }


def backoff_schedule(attempts: int, base_delay_us: int, seed: int):
    """Mirror of ``planner::recovery::backoff_schedule``, in microseconds:
    for each of the ``attempts - 1`` waits, the exponential base delay plus
    a seeded uniform jitter in ``[0, base * 2**i]`` drawn from the shared
    xoshiro256** stream via Lemire ``below``."""
    attempts = max(attempts, 1)
    rng = Rng(seed)
    delay = base_delay_us
    schedule = []
    for _ in range(1, attempts):
        span = min(delay, _M64 - 1)
        schedule.append(delay + rng.below(span + 1))
        delay = min(delay * 2, _M64)
    return schedule
